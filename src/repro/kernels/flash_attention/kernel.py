"""Pallas TPU flash-attention kernel (GQA, causal, sliding-window).

TPU-native layout decisions (vs. a CUDA port):

* Grid = (batch, q_head, q_block, k_block); the k_block axis is the
  innermost "arbitrary" dimension so the online-softmax accumulators
  live in VMEM scratch across k steps and the MXU sees back-to-back
  [block_q, D] x [D, block_k] matmuls.
* GQA is expressed in the BlockSpec index_map (``h // group``) — the
  shared KV block is fetched once per q-head group from HBM; no
  materialised head expansion.
* Block shapes default to (512, 512) on the sequence dims and keep the
  full head_dim (128/256): q/k/v/acc tiles fit comfortably in ~16 MB
  VMEM and every matmul dim is a multiple of the 128-lane MXU.
* Causal + sliding-window block pruning happens on the grid: fully
  masked k-blocks are skipped with ``pl.when`` (a TPU-friendly
  alternative to CUDA early-exit warps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    causal: bool,
    window: int,
    scale: float,
    block_q: int,
    block_k: int,
    kv_len: int,
    num_k_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    # block-level pruning: causal (k entirely in the future) and window
    # (k entirely too far in the past)
    live = k_start < kv_len
    if causal:
        live &= k_start <= q_start + block_q - 1
    if window > 0:
        live &= k_start + block_k - 1 > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [BQ, D]
        k = k_ref[0, 0].astype(jnp.float32)                  # [BK, D]
        v = v_ref[0, 0].astype(jnp.float32)                  # [BK, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                    # [BQ, BK]
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = k_pos < kv_len
        if causal:
            ok &= k_pos <= q_pos
        if window > 0:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "block_q",
        "block_k",
        "interpret",
    ),
)
def flash_attention_kernel(
    q: jax.Array,          # [B, Sq, H, D]
    k: jax.Array,          # [B, Skv, KV, D]
    v: jax.Array,          # [B, Skv, KV, D]
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    nq = (Sq + block_q - 1) // block_q
    nk = (Skv + block_k - 1) // block_k

    # head-major layout for clean [S, D] tiles
    qt = q.transpose(0, 2, 1, 3)  # [B, H, Sq, D]
    kt = k.transpose(0, 2, 1, 3)  # [B, KV, Skv, D]
    vt = v.transpose(0, 2, 1, 3)
    pad_q = nq * block_q - Sq
    pad_k = nk * block_k - Skv
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _attn_kernel,
        causal=causal,
        window=window,
        scale=1.0 / (D ** 0.5),
        block_q=block_q,
        block_k=block_k,
        kv_len=Skv,
        num_k_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, D), q.dtype),
        scratch_shapes=[
            _vmem((block_q,), jnp.float32),
            _vmem((block_q,), jnp.float32),
            _vmem((block_q, D), jnp.float32),
        ],
        compiler_params=_compiler_params(interpret),
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :, :Sq].transpose(0, 2, 1, 3)  # [B, Sq, H, D]
    return out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _compiler_params(interpret: bool):
    if interpret:
        return None
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
    )
