"""Chunked RWKV-6 scan — jit wrapper + chunked-jnp implementation.

The naive recurrence (ref.py) is O(S) sequential steps with an
[N x N] state update each — hopeless on the MXU. The chunked form
processes C tokens per step with three dense matmuls (TPU-native
reformulation of the GPU "flash-linear-attention" trick):

    E_j   = prod_{t<j} w_t                    (exclusive cumprod, via a
                                               triangular matmul in-kernel)
    out_j = (r_j . E_j) S_in                  [C,N] x [N,N]
          + [(r.E) (k/E')^T  o  mask_strict + diag(r.(u.k))] V
    S_out = diag(E_C) S_in + (k/E' . E_C)^T V

where E'_i = E_{i+1}. Cross-chunk state is carried sequentially
(lax.scan here; an 'arbitrary' grid dimension with VMEM scratch in the
Pallas kernel).

Numerics: ratios E_C/E' are bounded by clamping per-step log-decay at
``LOG_W_MIN`` (RWKV-6's w = exp(-exp(x)) rarely exceeds it) and keeping
the chunk short (default 16); everything is f32 inside the chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

LOG_W_MIN = -5.0


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def rwkv6_scan(
    r: jax.Array,          # [B, S, H, N]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,          # decays in (0, 1)
    u: jax.Array,          # [H, N]
    state0: jax.Array | None = None,   # [B, H, N, N] f32
    *,
    chunk: int = 16,
    impl: str = "auto",
    interpret: bool = False,
):
    """Returns (out [B,S,H,N], state [B,H,N,N])."""
    B, S, H, N = r.shape
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)
    # pad ragged sequences; w=1, k=0 is the identity state update
    C = min(chunk, S)
    pad = (C - S % C) % C
    if pad:
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zpad(r), zpad(k), zpad(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    use_kernel = impl == "kernel" or (
        impl == "auto" and jax.default_backend() == "tpu"
    )
    if use_kernel:
        from .kernel import rwkv6_scan_kernel

        out, state = rwkv6_scan_kernel(
            r, k, v, w, u, state0, chunk=chunk, interpret=interpret
        )
    else:
        out, state = _rwkv6_chunked(r, k, v, w, u, state0, chunk=chunk)
    return (out[:, :S], state) if pad else (out, state)


def _rwkv6_chunked(r, k, v, w, u, state0, *, chunk):
    B, S, H, N = r.shape
    C = min(chunk, S)
    assert S % C == 0, f"seq {S} must be divisible by chunk {C}"
    n_chunks = S // C
    f32 = jnp.float32

    def to_chunks(x):  # [B,S,H,N] -> [n, B, H, C, N]
        return (
            x.astype(f32)
            .reshape(B, n_chunks, C, H, N)
            .transpose(1, 0, 3, 2, 4)
        )

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))
    uf = u.astype(f32)
    tri_incl = jnp.tril(jnp.ones((C, C), f32))           # inclusive cumsum
    tri_excl = jnp.tril(jnp.ones((C, C), f32), k=-1)     # exclusive
    mask_strict = jnp.tril(jnp.ones((C, C), bool), k=-1)

    def step(S_, xs):
        r_, k_, v_, w_ = xs  # [B, H, C, N]
        logw = jnp.maximum(jnp.log(jnp.maximum(w_, 1e-30)), LOG_W_MIN)
        Lx = jnp.einsum("ij,bhjn->bhin", tri_excl, logw)   # exclusive cumsum
        Li = Lx + logw                                     # inclusive
        E = jnp.exp(Lx)                                    # prod_{t<j} w_t
        Etot = jnp.exp(Li[..., -1:, :])                    # [B,H,1,N]
        q_ = r_ * E
        k_div = k_ * jnp.exp(-Li)                          # k / E'
        A = jnp.einsum("bhin,bhjn->bhij", q_, k_div)
        A = jnp.where(mask_strict[None, None], A, 0.0)
        # diagonal (bonus-u) term, per head
        d = jnp.einsum("bhin,hn->bhi", r_ * k_, uf)
        out = (
            jnp.einsum("bhin,bhnm->bhim", q_, S_)
            + jnp.einsum("bhij,bhjn->bhin", A, v_)
            + d[..., None] * v_
        )
        k_carry = k_div * Etot                             # k . E_C/E'
        S_new = Etot[..., 0, :, None] * S_ + jnp.einsum(
            "bhin,bhim->bhnm", k_carry, v_
        )
        return S_new, out

    state, outs = jax.lax.scan(step, state0.astype(f32), (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, N)
    return out.astype(r.dtype), state


def rwkv6_decode_step(r, k, v, w, u, state):
    """Single-token recurrence for serving. r/k/v/w: [B, H, N]."""
    f32 = jnp.float32
    rf, kf, vf, wf = (x.astype(f32) for x in (r, k, v, w))
    uf = u.astype(f32)
    kv = kf[..., :, None] * vf[..., None, :]
    out = jnp.einsum("bhn,bhnm->bhm", rf, state + uf[None, :, :, None] * kv)
    state_new = wf[..., :, None] * state + kv
    return out.astype(r.dtype), state_new


__all__ = ["rwkv6_scan", "rwkv6_decode_step", "LOG_W_MIN"]
