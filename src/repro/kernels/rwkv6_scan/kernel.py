"""Pallas TPU kernel for the chunked RWKV-6 scan.

Grid = (B*H, n_chunks): heads are embarrassingly parallel, the chunk
axis is 'arbitrary' (sequential) with the [N, N] recurrent state held in
VMEM scratch between chunk steps — the TPU-native substitute for the
GPU kernel's per-SM shared-memory state. All chunk math is three MXU
matmuls plus a triangular-matmul cumsum (no in-kernel cumsum primitive
needed); everything is f32 in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ops import LOG_W_MIN


def _rwkv6_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
    out_ref, sout_ref,
    state_scr,
    *,
    chunk: int,
    n_chunks: int,
):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    C = chunk
    f32 = jnp.float32
    r_ = r_ref[0].astype(f32)          # [C, N]
    k_ = k_ref[0].astype(f32)
    v_ = v_ref[0].astype(f32)
    w_ = w_ref[0].astype(f32)
    u_ = u_ref[0].astype(f32)          # [N]
    S_ = state_scr[...]                # [N, N]

    iota_i = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    tri_excl = (iota_j < iota_i).astype(f32)
    mask_strict = iota_j < iota_i

    logw = jnp.maximum(jnp.log(jnp.maximum(w_, 1e-30)), LOG_W_MIN)
    Lx = jax.lax.dot_general(
        tri_excl, logw, (((1,), (0,)), ((), ())),
        preferred_element_type=f32,
    )                                   # exclusive cumsum [C, N]
    Li = Lx + logw
    E = jnp.exp(Lx)
    Etot = jnp.exp(Li[-1:, :])          # [1, N]
    q_ = r_ * E
    k_div = k_ * jnp.exp(-Li)

    A = jax.lax.dot_general(
        q_, k_div, (((1,), (1,)), ((), ())), preferred_element_type=f32
    )                                   # [C, C]
    A = jnp.where(mask_strict, A, 0.0)
    d = jnp.sum(r_ * k_ * u_[None, :], axis=1)  # [C]

    out = (
        jax.lax.dot_general(q_, S_, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)
        + jax.lax.dot_general(A, v_, (((1,), (0,)), ((), ())),
                              preferred_element_type=f32)
        + d[:, None] * v_
    )
    out_ref[0] = out.astype(out_ref.dtype)

    k_carry = k_div * Etot
    S_new = Etot.T * S_ + jax.lax.dot_general(
        k_carry, v_, (((0,), (0,)), ((), ())), preferred_element_type=f32
    )
    state_scr[...] = S_new

    @pl.when(c == n_chunks - 1)
    def _final():
        sout_ref[0] = S_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan_kernel(
    r, k, v, w, u, state0, *, chunk: int = 16, interpret: bool = False
):
    B, S, H, N = r.shape
    C = min(chunk, S)
    assert S % C == 0
    n_chunks = S // C

    def flat(x):  # [B,S,H,N] -> [B*H, S, N]
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, N)

    rf, kf, vf, wf = map(flat, (r, k, v, w))
    s0 = state0.reshape(B * H, N, N)

    grid = (B * H, n_chunks)
    seq_spec = pl.BlockSpec((1, C, N), lambda bh, c: (bh, c, 0))
    out, sout = pl.pallas_call(
        functools.partial(_rwkv6_kernel, chunk=C, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            seq_spec,
            seq_spec,
            seq_spec,
            seq_spec,
            pl.BlockSpec((1, N), lambda bh, c, H=H: (bh % H, 0)),
            pl.BlockSpec((1, N, N), lambda bh, c: (bh, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, N, N), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, N), r.dtype),
            jax.ShapeDtypeStruct((B * H, N, N), jnp.float32),
        ],
        scratch_shapes=[_vmem((N, N), jnp.float32)],
        compiler_params=_compiler_params(interpret),
        interpret=interpret,
    )(rf, kf, vf, wf, u, s0)

    out = out.reshape(B, H, S, N).transpose(0, 2, 1, 3)
    return out, sout.reshape(B, H, N, N)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _compiler_params(interpret: bool):
    if interpret:
        return None
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.CompilerParams(
        dimension_semantics=("parallel", "arbitrary")
    )
