"""Pure-jnp oracle for the RWKV-6 (Finch) recurrence.

Per head (head_dim = N), with receptance r_t, key k_t, value v_t in R^N,
data-dependent decay w_t in (0,1)^N and bonus u in R^N:

    S_t   = diag(w_t) . S_{t-1} + k_t^T v_t          (S in R^{N x N})
    out_t = r_t . (S_{t-1} + diag(u) . k_t^T v_t)

i.e. the current token contributes through the bonus u rather than the
decayed state — the defining RWKV quirk. The oracle is a direct
``lax.scan`` over time in f32; the Pallas kernel and the chunked jnp
implementation (ops.py) are validated against it.

Shapes: r/k/v/w [B, S, H, N]; u [H, N]; state [B, H, N, N]
(rows = key dim, cols = value dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=())
def rwkv6_ref(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state0: jax.Array | None = None,
):
    B, S, H, N = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if state0 is None:
        state0 = jnp.zeros((B, H, N, N), jnp.float32)

    def step(S_, xs):
        r_t, k_t, v_t, w_t = xs  # each [B, H, N]
        kv = k_t[..., :, None] * v_t[..., None, :]          # [B,H,N,N]
        out = jnp.einsum(
            "bhn,bhnm->bhm", r_t, S_ + uf[None, :, :, None] * kv
        )
        S_new = w_t[..., :, None] * S_ + kv
        return S_new, out

    xs = tuple(
        x.transpose(1, 0, 2, 3) for x in (rf, kf, vf, wf)
    )  # [S, B, H, N]
    state, outs = jax.lax.scan(step, state0, xs)
    out = outs.transpose(1, 0, 2, 3)  # [B, S, H, N]
    return out.astype(r.dtype), state
