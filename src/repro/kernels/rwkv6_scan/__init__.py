from .ops import rwkv6_scan, rwkv6_decode_step
from .ref import rwkv6_ref

__all__ = ["rwkv6_scan", "rwkv6_decode_step", "rwkv6_ref"]
