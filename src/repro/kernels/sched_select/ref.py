"""Pure-jnp reference for the fused masked-selection op (Pallas phase 2).

Every decision slot of every scheduler event runs one or two *masked
lexicographic selections* over the pipeline / container tables:

* ``select_next_pipe`` — highest priority, then earliest (re-)entry
  tick, then lowest pid (the waiting queue without a materialised
  queue), and
* ``select_victim``   — lowest priority, then latest start tick, then
  lowest slot (the preemption victim).

The seed implementation ran each as three full masked max/argmax
reductions plus an ``any`` (§scheduler.py pre-PR-4) — 4 passes over the
table per call, per decision slot, per event, per lane. Here the whole
selection collapses into ONE fused primitive:

    masked_lex_argmin(mask, keys) -> index of the lexicographically
    smallest (keys[0][i], ..., keys[-1][i], i) among mask, or -1

computed with a single narrowing sweep — ``len(keys)`` masked
reductions total (min per key + one first-index argmin), no ``any``,
no argmax repair passes. The index tie-break is free: ``argmin`` picks
the first occurrence of the minimum, exactly the old ``argmax(m3)``.

Bitwise contract (property-tested in tests/test_sched_select.py): the
returned index is identical to the three-pass helpers for every input
in the engine's domain —

* masked entries have ``keys[0] < INT32_MAX`` (priorities are small),
* when candidates survive to the last key, their minimum is
  ``< INT32_MAX`` (entry/start ticks are real ticks, not INF_TICK).

Both hold by construction in the simulator (WAITING pipes have
``entered <= tick < INF_TICK``; live containers have ``start >= 0``);
the sentinels below collide with neither.

Shapes: the reference reduces the LAST axis, so it serves both the
per-lane [N] form (vmapped by the engine into [F, N] batched
reductions) and the explicit lane-major [F, N] form the Pallas kernel
tiles (``kernel.py``).
"""
from __future__ import annotations

import jax.numpy as jnp

# sentinel: larger than any in-domain key (python int so the Pallas
# kernel can close over it without capturing a traced constant)
BIG = 2**31 - 1


def masked_lex_argmin_ref(mask, keys):
    """Index of the lexicographic minimum of ``zip(*keys, index)`` over
    ``mask`` (reduced along the last axis), ``-1`` where the mask is
    empty. ``keys`` is a sequence of int32 arrays shaped like ``mask``.
    """
    keys = tuple(keys)
    m = mask
    empty = None
    for k in keys[:-1]:
        km = jnp.where(m, k, BIG)
        b = jnp.min(km, axis=-1, keepdims=True)
        if empty is None:
            empty = b[..., 0] == BIG
        m = km == b
    km = jnp.where(m, keys[-1], BIG)
    if empty is None:  # single-key selection
        empty = jnp.min(km, axis=-1) == BIG
    idx = jnp.argmin(km, axis=-1).astype(jnp.int32)
    return jnp.where(empty, jnp.int32(-1), idx)


def select_next_pipe_ref(mask, prio, entered):
    """Fused waiting-queue head: priority desc, entry asc, pid asc."""
    return masked_lex_argmin_ref(mask, (-prio, entered))


def select_victim_ref(live, ctr_prio, ctr_start, below_prio):
    """Fused preemption victim: among live containers strictly below
    ``below_prio``: priority asc, start desc (least progress lost),
    slot asc."""
    m = live & (ctr_prio < below_prio)
    return masked_lex_argmin_ref(m, (ctr_prio, -ctr_start))


def select_sjf_ref(mask, n_ops, prio, entered):
    """Fused smallest-job-first head: op count asc, priority desc,
    entry asc, pid asc (``extra_schedulers``)."""
    return masked_lex_argmin_ref(mask, (n_ops, -prio, entered))
