"""Pallas TPU kernel for the fused masked lexicographic selection.

One grid step selects over a [FB, N] tile of the fleet x table batch
entirely in VMEM: the candidate mask narrows once per key (masked
row-min + compare, all VPU), and the final first-index tie-break is a
masked row-min over a column iota — the whole selection is a single
pass over the tile, where the seed's three-pass helpers re-read the
table once per reduction. Keys arrive stacked as [FB, K, N] so the
tile pair (mask + keys) is the unit of HBM traffic.

Per-lane scalar outputs (the winning indices) are emitted as [FB, 8]
tiles (sublane-aligned broadcast, the same convention as
``kernels/sim_tick``); the dispatch wrapper takes column 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BIG


def _select_kernel(mask_ref, keys_ref, idx_ref, *, num_keys: int):
    m = mask_ref[...] != 0                       # [FB, N]
    n = m.shape[1]
    empty = None
    for j in range(num_keys - 1):
        km = jnp.where(m, keys_ref[:, j, :], BIG)
        b = jnp.min(km, axis=1, keepdims=True)   # [FB, 1]
        if empty is None:
            empty = b == BIG
        m = km == b
    km = jnp.where(m, keys_ref[:, num_keys - 1, :], BIG)
    if empty is None:
        empty = jnp.min(km, axis=1, keepdims=True) == BIG
    b = jnp.min(km, axis=1, keepdims=True)
    # first index achieving the minimum == jnp.argmin's tie-break
    col = jax.lax.broadcasted_iota(jnp.int32, m.shape, 1)
    idx = jnp.min(
        jnp.where(km == b, col, jnp.int32(n)), axis=1, keepdims=True
    )
    out = jnp.where(empty, jnp.int32(-1), idx)   # [FB, 1]
    idx_ref[...] = jnp.broadcast_to(out, idx_ref.shape)


@functools.partial(
    jax.jit, static_argnames=("block_fleet", "interpret")
)
def masked_lex_argmin_kernel(
    mask, keys, *, block_fleet: int = 256, interpret: bool = False
):
    """``mask`` [F, N] bool/int, ``keys`` [F, K, N] int32 -> [F] int32
    (lexicographic argmin with index tie-break, -1 on empty mask)."""
    F, N = mask.shape
    K = keys.shape[1]
    FB = min(block_fleet, F)
    # pad the fleet axis to a whole number of tiles; padding lanes carry
    # all-false masks, so their output is -1 and is sliced off below
    pad = (-F) % FB
    if pad:
        mask = jnp.concatenate(
            [mask, jnp.zeros((pad, N), mask.dtype)], axis=0
        )
        keys = jnp.concatenate(
            [keys, jnp.zeros((pad, K, N), keys.dtype)], axis=0
        )
    FP = F + pad
    out = pl.pallas_call(
        functools.partial(_select_kernel, num_keys=K),
        grid=(FP // FB,),
        in_specs=[
            pl.BlockSpec((FB, N), lambda i: (i, 0)),
            pl.BlockSpec((FB, K, N), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((FB, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((FP, 8), jnp.int32),
        interpret=interpret,
    )(mask.astype(jnp.int32), keys)
    return out[:F, 0]
