from .ops import (
    masked_lex_argmin,
    select_next_pipe,
    select_sjf,
    select_victim,
)
from .ref import (
    masked_lex_argmin_ref,
    select_next_pipe_ref,
    select_sjf_ref,
    select_victim_ref,
)

__all__ = [
    "masked_lex_argmin",
    "select_next_pipe",
    "select_sjf",
    "select_victim",
    "masked_lex_argmin_ref",
    "select_next_pipe_ref",
    "select_sjf_ref",
    "select_victim_ref",
]
