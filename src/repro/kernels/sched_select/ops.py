"""Dispatch wrapper for the fused masked-selection op (Pallas phase 2).

``impl="auto"`` picks the Pallas kernel on TPU for explicit lane-major
[F, N] batches and the bitwise-equivalent jnp reference everywhere
else. The per-lane [N] form — what the schedulers trace under the
engine's ``vmap`` — always lowers through the reference: under vmap
its fused reductions batch into exactly the [F, N] shape the kernel
tiles, so the hot path is identical maths either way and the vmapped
while_loop stays free of pallas batching constraints.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import masked_lex_argmin_kernel
from .ref import masked_lex_argmin_ref


def masked_lex_argmin(mask, keys, *, impl: str = "auto", interpret: bool = False):
    """Index of the lexicographically smallest ``(*keys[i], i)`` among
    ``mask`` along the last axis, ``-1`` where the mask is empty.

    See ``ref.masked_lex_argmin_ref`` for the bitwise contract vs the
    seed three-pass helpers (``scheduler.select_next_pipe`` /
    ``select_victim`` remain exported as the oracles).
    """
    keys = tuple(keys)
    use_kernel = impl == "kernel" or (
        impl == "auto" and jax.default_backend() == "tpu" and mask.ndim == 2
    )
    if use_kernel:
        return masked_lex_argmin_kernel(
            mask, jnp.stack(keys, axis=-2), interpret=interpret
        )
    return masked_lex_argmin_ref(mask, keys)


def select_next_pipe(mask, prio, entered, *, impl: str = "auto"):
    """Fused waiting-queue head (priority desc, entry asc, pid asc)."""
    return masked_lex_argmin(mask, (-prio, entered), impl=impl)


def select_victim(live, ctr_prio, ctr_start, below_prio, *, impl: str = "auto"):
    """Fused preemption victim (priority asc, start desc, slot asc)."""
    m = live & (ctr_prio < below_prio)
    return masked_lex_argmin(m, (ctr_prio, -ctr_start), impl=impl)


def select_sjf(mask, n_ops, prio, entered, *, impl: str = "auto"):
    """Fused smallest-job-first head (ops asc, prio desc, entry asc)."""
    return masked_lex_argmin(mask, (n_ops, -prio, entered), impl=impl)


__all__ = [
    "masked_lex_argmin",
    "select_next_pipe",
    "select_victim",
    "select_sjf",
]
