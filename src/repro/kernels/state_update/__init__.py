from .ops import assign_gather, retire_land
from .ref import assign_gather_ref, retire_land_ref

__all__ = [
    "retire_land",
    "assign_gather",
    "retire_land_ref",
    "assign_gather_ref",
]
