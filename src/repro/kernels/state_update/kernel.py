"""Pallas TPU kernels for the fused state-update landings (phase 3).

Same house conventions as ``kernels/sim_tick``: one grid step processes
a fleet block entirely in VMEM, scalar-per-lane outputs are emitted as
[FB, 8] sublane-aligned tiles (the dispatch wrapper takes column 0),
and the fleet axis is zero-padded to whole tiles (padding lanes produce
garbage that is sliced off — nothing reduces across the fleet axis).

The one-hot landings materialise a rank-3 [FB, MC, MP] (retire) or
[FB, K, MC]/[FB, K, MP] (assign) mask in VMEM, so the default fleet
blocks are sized small enough that the biggest intermediate stays well
under the ~16 MB VMEM budget for the repo's table sizes (MC<=128,
MP<=512, K<=16): 8 lanes x 128 x 512 x 4 B = 2 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import INF_TICK, N_PRIO, TICKS_PER_SECOND  # noqa: F401

_iota = jax.lax.broadcasted_iota


def _retire_kernel(
    pipe_ref, end_ref, start_ref, oomed_ref, done_ref, timed_ref,
    arr_ref, prio_ref, tick_ref,
    oomh_ref, doneh_ref, timedh_ref, endof_ref, wasted_ref,
    latsum_ref, latprio_ref, doneprio_ref, ndone_ref, noom_ref,
    *,
    timeout_on: bool,
):
    i32 = jnp.int32
    FB, MC = pipe_ref.shape
    MP = arr_ref.shape[1]
    t = tick_ref[...][:, :1]                       # [FB, 1]
    oomed = oomed_ref[...] != 0
    done = done_ref[...] != 0
    retired = oomed | done
    if timeout_on:
        timed = done & (timed_ref[...] != 0)
        done_eff = done & ~timed
    else:
        timed = jnp.zeros_like(done)
        done_eff = done

    pid = jnp.where(retired, pipe_ref[...], MP)
    oh = pid[:, :, None] == _iota(i32, (FB, MC, MP), 2)
    oom_hit = jnp.any(oh & oomed[:, :, None], axis=1)
    done_hit = jnp.any(oh & done_eff[:, :, None], axis=1)
    end_of = jnp.max(
        jnp.where(oh & done_eff[:, :, None], end_ref[...][:, :, None], 0),
        axis=1,
    )
    oomh_ref[...] = oom_hit.astype(i32)
    doneh_ref[...] = done_hit.astype(i32)
    endof_ref[...] = end_of
    if timeout_on:
        timedh_ref[...] = jnp.any(oh & timed[:, :, None], axis=1).astype(i32)
        wasted = jnp.sum(
            jnp.where(timed, t - start_ref[...], 0), axis=1, keepdims=True
        )
    else:
        timedh_ref[...] = jnp.zeros((FB, MP), i32)
        wasted = jnp.zeros((FB, 1), i32)
    wasted_ref[...] = jnp.broadcast_to(wasted, wasted_ref.shape)

    lat_s = (end_of - arr_ref[...]).astype(jnp.float32) / TICKS_PER_SECOND
    lat_s = jnp.where(done_hit, lat_s, 0.0)
    prio_oh = prio_ref[...][:, None, :] == _iota(i32, (FB, N_PRIO, MP), 1)
    latsum = jnp.sum(lat_s, axis=1, keepdims=True)
    latsum_ref[...] = jnp.broadcast_to(latsum, latsum_ref.shape)
    latprio_ref[...] = jnp.sum(
        jnp.where(prio_oh, lat_s[:, None, :], 0.0), axis=2
    )
    doneprio_ref[...] = jnp.sum(
        (prio_oh & done_hit[:, None, :]).astype(i32), axis=2
    )
    ndone = jnp.sum(done_hit.astype(i32), axis=1, keepdims=True)
    ndone_ref[...] = jnp.broadcast_to(ndone, ndone_ref.shape)
    noom = jnp.sum(oom_hit.astype(i32), axis=1, keepdims=True)
    noom_ref[...] = jnp.broadcast_to(noom, noom_ref.shape)


@functools.partial(
    jax.jit, static_argnames=("timeout_on", "block_fleet", "interpret")
)
def retire_land_kernel(
    ctr_pipe, ctr_end, ctr_start, oomed, done, timed, arrival, prio, tick,
    *, timeout_on: bool = False, block_fleet: int = 8,
    interpret: bool = False,
):
    F, MC = ctr_pipe.shape
    MP = arrival.shape[1]
    FB = min(block_fleet, F)
    pad = (-F) % FB
    if pad:
        def padded(x):
            return jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
            )

        ctr_pipe, ctr_end, ctr_start, arrival, prio, tick = map(
            padded, (ctr_pipe, ctr_end, ctr_start, arrival, prio, tick)
        )
        oomed, done, timed = map(padded, (oomed, done, timed))
    FP = F + pad
    grid = (FP // FB,)
    tick2 = jnp.broadcast_to(tick[:, None], (FP, 8)).astype(jnp.int32)

    ctile = pl.BlockSpec((FB, MC), lambda i: (i, 0))
    ptile = pl.BlockSpec((FB, MP), lambda i: (i, 0))
    prio_tile = pl.BlockSpec((FB, N_PRIO), lambda i: (i, 0))
    reg_tile = pl.BlockSpec((FB, 8), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_retire_kernel, timeout_on=timeout_on),
        grid=grid,
        in_specs=[ctile, ctile, ctile, ctile, ctile, ctile,
                  ptile, ptile, reg_tile],
        out_specs=[ptile, ptile, ptile, ptile, reg_tile,
                   reg_tile, prio_tile, prio_tile, reg_tile, reg_tile],
        out_shape=[
            jax.ShapeDtypeStruct((FP, MP), jnp.int32),
            jax.ShapeDtypeStruct((FP, MP), jnp.int32),
            jax.ShapeDtypeStruct((FP, MP), jnp.int32),
            jax.ShapeDtypeStruct((FP, MP), jnp.int32),
            jax.ShapeDtypeStruct((FP, 8), jnp.int32),
            jax.ShapeDtypeStruct((FP, 8), jnp.float32),
            jax.ShapeDtypeStruct((FP, N_PRIO), jnp.float32),
            jax.ShapeDtypeStruct((FP, N_PRIO), jnp.int32),
            jax.ShapeDtypeStruct((FP, 8), jnp.int32),
            jax.ShapeDtypeStruct((FP, 8), jnp.int32),
        ],
        interpret=interpret,
    )(ctr_pipe, ctr_end, ctr_start, oomed.astype(jnp.int32),
      done.astype(jnp.int32), timed.astype(jnp.int32),
      arrival, prio, tick2)
    (oomh, doneh, timedh, endof, wasted,
     latsum, latprio, doneprio, ndone, noom) = outs
    return (
        oomh[:F].astype(bool), doneh[:F].astype(bool),
        timedh[:F].astype(bool), endof[:F], wasted[:F, 0],
        latsum[:F, 0], latprio[:F], doneprio[:F], ndone[:F, 0], noom[:F, 0],
    )


def _assign_kernel(
    valid_ref, slot_ref, pipe_ref, pool_ref, cpus_ref, ram_ref,
    end_ref, oom_ref, prio_ref, warm_ref, timed_ref,
    hitc_ref, lpipe_ref, lpool_ref, lcpus_ref, lram_ref, lend_ref,
    loom_ref, lprio_ref, lwarm_ref, ltimed_ref,
    hitp_ref, lpcpus_ref, lpram_ref,
):
    i32 = jnp.int32
    FB, K = valid_ref.shape
    MC = hitc_ref.shape[1]
    MP = hitp_ref.shape[1]
    valid = valid_ref[...] != 0

    oh_c = (slot_ref[...][:, :, None] == _iota(i32, (FB, K, MC), 2)) & valid[
        :, :, None
    ]
    hitc_ref[...] = jnp.any(oh_c, axis=1).astype(i32)

    def land_c(x, fill=0):
        return jnp.sum(jnp.where(oh_c, x[:, :, None], fill), axis=1)

    lpipe_ref[...] = land_c(pipe_ref[...])
    lpool_ref[...] = land_c(pool_ref[...])
    lcpus_ref[...] = land_c(cpus_ref[...], 0.0)
    lram_ref[...] = land_c(ram_ref[...], 0.0)
    lend_ref[...] = land_c(end_ref[...])
    loom_ref[...] = land_c(oom_ref[...])
    lprio_ref[...] = land_c(prio_ref[...])
    lwarm_ref[...] = jnp.any(
        oh_c & (warm_ref[...] != 0)[:, :, None], axis=1
    ).astype(i32)
    ltimed_ref[...] = jnp.any(
        oh_c & (timed_ref[...] != 0)[:, :, None], axis=1
    ).astype(i32)

    oh_p = (pipe_ref[...][:, :, None] == _iota(i32, (FB, K, MP), 2)) & valid[
        :, :, None
    ]
    hitp_ref[...] = jnp.any(oh_p, axis=1).astype(i32)
    lpcpus_ref[...] = jnp.sum(
        jnp.where(oh_p, cpus_ref[...][:, :, None], 0.0), axis=1
    )
    lpram_ref[...] = jnp.sum(
        jnp.where(oh_p, ram_ref[...][:, :, None], 0.0), axis=1
    )


@functools.partial(
    jax.jit,
    static_argnames=("max_containers", "max_pipelines", "block_fleet",
                     "interpret"),
)
def assign_gather_kernel(
    valid, slot, pipe, pool, cpus, ram, end, oom, prio, warm, timed,
    *, max_containers: int, max_pipelines: int, block_fleet: int = 64,
    interpret: bool = False,
):
    F, K = valid.shape
    MC, MP = max_containers, max_pipelines
    FB = min(block_fleet, F)
    pad = (-F) % FB
    rows = (valid, slot, pipe, pool, cpus, ram, end, oom, prio, warm, timed)
    if pad:
        rows = tuple(
            jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
            )
            for x in rows
        )
    FP = F + pad
    grid = (FP // FB,)
    row_tile = pl.BlockSpec((FB, K), lambda i: (i, 0))
    ctile = pl.BlockSpec((FB, MC), lambda i: (i, 0))
    ptile = pl.BlockSpec((FB, MP), lambda i: (i, 0))

    def c_out(dt):
        return jax.ShapeDtypeStruct((FP, MC), dt)

    def p_out(dt):
        return jax.ShapeDtypeStruct((FP, MP), dt)

    outs = pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[row_tile] * 11,
        out_specs=[ctile] * 10 + [ptile] * 3,
        out_shape=[
            c_out(jnp.int32), c_out(jnp.int32), c_out(jnp.int32),
            c_out(jnp.float32), c_out(jnp.float32), c_out(jnp.int32),
            c_out(jnp.int32), c_out(jnp.int32), c_out(jnp.int32),
            c_out(jnp.int32),
            p_out(jnp.int32), p_out(jnp.float32), p_out(jnp.float32),
        ],
        interpret=interpret,
    )(rows[0].astype(jnp.int32), *rows[1:9],
      rows[9].astype(jnp.int32), rows[10].astype(jnp.int32))
    (hitc, lpipe, lpool, lcpus, lram, lend, loom, lprio, lwarm, ltimed,
     hitp, lpcpus, lpram) = outs
    return (
        hitc[:F].astype(bool), lpipe[:F], lpool[:F], lcpus[:F], lram[:F],
        lend[:F], loom[:F], lprio[:F], lwarm[:F].astype(bool),
        ltimed[:F].astype(bool), hitp[:F].astype(bool), lpcpus[:F],
        lpram[:F],
    )
