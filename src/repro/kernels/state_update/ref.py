"""Pure-jnp oracle for the fused executor state-update passes (phase 3).

After the phase-1 read fusion (``kernels/sim_tick``) and the scheduler
selection fusion (``kernels/sched_select``), the remaining hot path of
the lane-major engine was the executor's *write* side: the chain of
per-pass ``.at[].set/add`` scatters that land retirements on the
pipeline table (``_apply_retirements``) and the per-slot ``lax.cond``
commit inside ``apply_decision``'s assignment loop, which selected the
ENTIRE SimState once per slot. This module fuses both landings:

* :func:`retire_land_ref` — the retirement landing: for each pipeline,
  did one of its containers OOM / complete / time out this event, what
  is the completion tick, and the latency / priority-bucket sums.
* :func:`assign_gather_ref` — the decision landing: the per-slot
  assignment *rows* collected by the (now tiny) early-exit loop are
  landed on the container and pipeline tables in one pass, instead of
  ~20 ``.at[slot].set`` writes under a full-state ``lax.cond`` per
  slot.

The reference computes the landings as masked one-hot reductions and
gathers over ``[MC, MP]`` — NOT the seed's ``.at[idx].add/max/set``
scatters. On XLA:CPU a dynamic-index scatter under the engine's
per-lane ``vmap`` lowers to a serialized ``while`` thunk per scatter
(~180us fixed cost each), so the scatter form is the slow one there;
the one-hot form lowers to elementwise ops + reduces and is also the
regular tiling form the Pallas kernel / MXU wants, so ref and kernel
share one shape. The one-hot forms are bitwise identical to the seed's
scatters:

* int/bool scatters with unique indices == one-hot masked reductions,
  exactly;
* the f32 landings (``cpus``/``ram``/latency terms) have at most one
  nonzero term per output element, and ``x + 0.0 == x`` bitwise for
  every ``x != -0.0`` (allocations and latencies are never ``-0.0``),
  so the kernel's one-hot sums are fp-exact too;
* order-sensitive f32 accumulators (pool frees, cache bytes) are NOT
  landed here — the executor carries them sequentially, preserving the
  seed's left-fold association.

Property-tested in tests/test_state_update.py against the sequential
oracles (``executor.process_*`` and the ``early_exit=False`` commit
loop), with ``interpret=True`` pinning kernel == ref on CPU CI.

Shapes (unbatched | batched): retire_land: ctr_* ``[MC] | [F, MC]``,
arrival/prio ``[MP] | [F, MP]``, tick ``[] | [F]``; assign_gather:
rows ``[K] | [F, K]``. The explicit batched form (what the kernel
tiles) dispatches through ``jax.vmap`` of the per-lane landing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

INF_TICK = 2**31 - 1
TICKS_PER_SECOND = 100_000  # types.TICK_SECONDS = 10 us (paper §3.2)
N_PRIO = 3


def _retire_land_1d(
    ctr_pipe, ctr_end, ctr_start, oomed, done, timed,
    arrival, prio, tick, timeout_on: bool,
):
    i32 = jnp.int32
    MP = arrival.shape[0]

    retired = oomed | done
    if timeout_on:
        timed = done & timed
        done_eff = done & ~timed
    else:
        timed = jnp.zeros_like(done)
        done_eff = done

    # the landing as one-hot reductions over [MC, MP] instead of the
    # seed's ``.at[pid].add/max`` scatters: batched scatters serialize
    # on XLA:CPU under the engine's per-lane vmap, while these lower to
    # elementwise ops + reduces. Aggregation semantics are preserved
    # bitwise — the hit counts are int sums (``> 0`` == any), ``end_of``
    # is an int max-fold — so duplicate ``ctr_pipe`` rows (several
    # containers of one pipeline retiring together) land exactly like
    # the scatters did.
    pid = jnp.where(retired, ctr_pipe, MP)
    oh = pid[:, None] == jnp.arange(MP, dtype=i32)[None, :]  # [MC, MP]
    oom_hit = jnp.any(oh & oomed[:, None], axis=0)
    done_hit = jnp.any(oh & done_eff[:, None], axis=0)
    end_of = jnp.max(
        jnp.where(
            oh & done_eff[:, None], ctr_end[:, None], jnp.int32(0)
        ),
        axis=0,
        initial=0,
    )
    if timeout_on:
        timed_hit = jnp.any(oh & timed[:, None], axis=0)
        timed_wasted = jnp.sum(jnp.where(timed, tick - ctr_start, 0)).astype(
            i32
        )
    else:
        timed_hit = jnp.zeros_like(done_hit)
        timed_wasted = jnp.zeros((), i32)

    lat_s = (end_of - arrival).astype(jnp.float32) / TICKS_PER_SECOND
    lat_s = jnp.where(done_hit, lat_s, 0.0)
    prio_oh = prio[None, :] == jnp.arange(N_PRIO, dtype=i32)[:, None]
    lat_sum = jnp.sum(lat_s)
    lat_prio = jnp.sum(jnp.where(prio_oh, lat_s[None, :], 0.0), axis=-1)
    done_prio = jnp.sum(prio_oh & done_hit[None, :], axis=-1).astype(i32)
    n_done = jnp.sum(done_hit).astype(i32)
    n_oom = jnp.sum(oom_hit).astype(i32)
    return (
        oom_hit, done_hit, timed_hit, end_of, timed_wasted,
        lat_sum, lat_prio, done_prio, n_done, n_oom,
    )


@functools.partial(jax.jit, static_argnames=("timeout_on",))
def retire_land_ref(
    ctr_pipe, ctr_end, ctr_start, oomed, done, timed,
    arrival, prio, tick, *, timeout_on: bool = False,
):
    """Land container retirements on the pipeline axis.

    ``timed`` (``done & ctr_timed``: the deadline kills) is consumed
    only when ``timeout_on``; pass any placeholder (e.g. ``done``)
    otherwise. Returns ``(oom_hit, done_hit, timed_hit, end_of,
    timed_wasted, lat_sum, lat_prio, done_prio, n_done, n_oom)`` —
    ``timed_hit``/``timed_wasted`` are zeros when ``timeout_on`` is
    False.
    """
    fn = functools.partial(_retire_land_1d, timeout_on=timeout_on)
    if ctr_pipe.ndim == 2:
        return jax.vmap(
            lambda cp, ce, cs, o, d, td, a, p, t: fn(
                cp, ce, cs, o, d, td, a, p, t
            )
        )(ctr_pipe, ctr_end, ctr_start, oomed, done, timed, arrival, prio,
          tick)
    return fn(ctr_pipe, ctr_end, ctr_start, oomed, done, timed, arrival,
              prio, tick)


def _assign_gather_1d(
    valid, slot, pipe, pool, cpus, ram, end, oom, prio, warm, timed,
    max_containers: int, max_pipelines: int,
):
    i32 = jnp.int32
    # valid rows carry unique slots/pipes (the loop consumes each empty
    # slot and waiting pipeline it assigns), so every output element has
    # at most one contributing row and the masked one-hot reductions are
    # exact (single-term sums; ``x + 0.0 == x`` bitwise for the f32
    # values, which are never ``-0.0``). One-hot instead of scatter
    # because batched scatters serialize on XLA:CPU under the engine's
    # per-lane vmap; these reduce to elementwise ops + reduces.
    sv = jnp.where(valid, slot, max_containers)
    pv = jnp.where(valid, pipe, max_pipelines)

    # one one-hot membership test per axis, then *gathers*: with unique
    # row indices, ``argmax`` over the one-hot recovers the (single)
    # contributing row per output element, and each landed field is one
    # [K]-to-[MC] gather instead of a full masked reduction per field
    oh_c = sv[:, None] == jnp.arange(max_containers, dtype=i32)[None, :]
    hit_c = jnp.any(oh_c, axis=0)
    rr_c = jnp.argmax(oh_c, axis=0)

    def land_c(x, dtype=i32):
        return jnp.where(hit_c, x.astype(dtype)[rr_c], dtype(0))

    l_pipe = land_c(pipe)
    l_pool = land_c(pool)
    l_cpus = land_c(cpus, jnp.float32)
    l_ram = land_c(ram, jnp.float32)
    l_end = land_c(end)
    l_oom = land_c(oom)
    l_prio = land_c(prio)
    l_warm = hit_c & warm[rr_c]
    l_timed = hit_c & timed[rr_c]

    oh_p = pv[:, None] == jnp.arange(max_pipelines, dtype=i32)[None, :]
    hit_p = jnp.any(oh_p, axis=0)
    rr_p = jnp.argmax(oh_p, axis=0)
    l_pcpus = jnp.where(hit_p, cpus[rr_p], jnp.float32(0))
    l_pram = jnp.where(hit_p, ram[rr_p], jnp.float32(0))

    return (
        hit_c, l_pipe, l_pool, l_cpus, l_ram, l_end, l_oom, l_prio,
        l_warm, l_timed, hit_p, l_pcpus, l_pram,
    )


@functools.partial(
    jax.jit, static_argnames=("max_containers", "max_pipelines")
)
def assign_gather_ref(
    valid, slot, pipe, pool, cpus, ram, end, oom, prio, warm, timed,
    *, max_containers: int, max_pipelines: int,
):
    """Land the collected assignment rows on the container/pipeline axes.

    Rows (``[.., K]``) come from the executor's early-exit loop; valid
    rows carry unique ``slot``/``pipe`` indices (the loop consumes each
    empty slot and waiting pipeline it assigns), so every output element
    has at most one contributing row.

    Returns ``(hit_c, l_pipe, l_pool, l_cpus, l_ram, l_end, l_oom,
    l_prio, l_warm, l_timed, hit_p, l_pcpus, l_pram)``: the container-
    axis landing (``hit_c`` [.., MC] plus the per-slot values) and the
    pipeline-axis landing (``hit_p`` [.., MP] plus the last-allocation
    values); the executor applies them with one ``where`` per field.
    """
    fn = functools.partial(
        _assign_gather_1d,
        max_containers=max_containers,
        max_pipelines=max_pipelines,
    )
    args = (valid, slot, pipe, pool, cpus, ram, end, oom, prio, warm, timed)
    if valid.ndim == 2:
        return jax.vmap(lambda *a: fn(*a))(*args)
    return fn(*args)
