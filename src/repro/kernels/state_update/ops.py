"""Dispatch wrappers for the fused state-update landings (Pallas phase 3).

Same convention as ``sim_tick`` and ``sched_select`` (documented once
in docs/architecture.md §"Kernel subsystems"): ``impl="auto"`` picks
the Pallas kernel on TPU for explicit lane-major 2-D batches and the
bitwise-equivalent jnp reference everywhere else. The per-lane form —
what the executor traces under the engine's ``vmap`` — always lowers
through the reference: under vmap its one-hot reductions batch into
exactly the shapes the kernel tiles, so the hot path is identical
maths either way and the vmapped while_loop stays free of pallas
batching constraints. The sequential seed passes remain exported as
the property-tested oracles (``executor.process_*`` and the
``early_exit=False`` commit loop).
"""
from __future__ import annotations

import jax.numpy as jnp

from ..dispatch import use_pallas
from .kernel import assign_gather_kernel, retire_land_kernel
from .ref import assign_gather_ref, retire_land_ref


def retire_land(
    ctr_pipe, ctr_end, ctr_start, oomed, done, timed, arrival, prio, tick,
    *, timeout_on: bool = False, impl: str = "auto", interpret: bool = False,
):
    """Fused retirement landing: per-pipeline OOM/done/timeout hit
    masks, completion ticks, and the latency/priority reductions, in
    one masked one-hot pass (see ``ref.retire_land_ref`` for the
    bitwise contract vs ``executor._apply_retirements``).

    ``timed`` may be ``None`` when ``timeout_on`` is False.
    """
    if timed is None:
        timed = jnp.zeros_like(done)
    if use_pallas(impl, batched=ctr_pipe.ndim == 2):
        return retire_land_kernel(
            ctr_pipe, ctr_end, ctr_start, oomed, done, timed, arrival,
            prio, tick, timeout_on=timeout_on, interpret=interpret,
        )
    return retire_land_ref(
        ctr_pipe, ctr_end, ctr_start, oomed, done, timed, arrival, prio,
        tick, timeout_on=timeout_on,
    )


def assign_gather(
    valid, slot, pipe, pool, cpus, ram, end, oom, prio, warm, timed,
    *, max_containers: int, max_pipelines: int, impl: str = "auto",
    interpret: bool = False,
):
    """Fused decision landing: scatter the collected assignment rows
    onto the container/pipeline axes as one batched masked pass (see
    ``ref.assign_gather_ref`` for the bitwise contract vs the
    per-slot ``lax.cond`` commits of ``apply_decision``)."""
    if use_pallas(impl, batched=valid.ndim == 2):
        return assign_gather_kernel(
            valid, slot, pipe, pool, cpus, ram, end, oom, prio, warm,
            timed, max_containers=max_containers,
            max_pipelines=max_pipelines, interpret=interpret,
        )
    return assign_gather_ref(
        valid, slot, pipe, pool, cpus, ram, end, oom, prio, warm, timed,
        max_containers=max_containers, max_pipelines=max_pipelines,
    )


__all__ = ["retire_land", "assign_gather"]
