"""NaN-guarded Pareto dominance over minimised objective vectors.

Objectives follow the minimise convention throughout (including
utilisation, see :data:`repro.search.grid.OBJECTIVES`). A NaN
objective — an empty lane, an all-shed scenario, a policy that finished
nothing — maps to +inf (PR-9 NaN-guard pattern): it can never dominate,
and anything finite dominates it, so degenerate candidates sink to the
back of every front instead of poisoning comparisons.

>>> import numpy as np
>>> dominates([1.0, 2.0], [2.0, 2.0])
True
>>> dominates([1.0, 2.0], [1.0, 2.0])  # ties: equal points don't dominate
False
>>> weakly_dominates([1.0, 2.0], [1.0, 2.0])
True
>>> dominates([1.0, float("nan")], [2.0, 3.0])  # NaN -> +inf, can't win
False
>>> dominates([1.0, 3.0], [1.0, float("nan")])  # ...and finite beats it
True
>>> pareto_front([[1.0, 4.0], [2.0, 3.0], [3.0, 3.0], [2.0, 5.0]]).tolist()
[0, 1]
>>> pareto_front([[7.0, 7.0]]).tolist()  # single candidate IS the front
[0]
>>> pareto_front(np.empty((0, 2))).tolist()
[]
"""
from __future__ import annotations

import numpy as np


def sanitize(objs) -> np.ndarray:
    """Objective matrix as float64 with every NaN replaced by +inf."""
    objs = np.asarray(objs, np.float64)
    return np.where(np.isnan(objs), np.inf, objs)


def dominates(a, b) -> bool:
    """True iff ``a`` is no worse than ``b`` everywhere and strictly
    better somewhere (both minimised; NaN = +inf)."""
    a, b = sanitize(a), sanitize(b)
    return bool(np.all(a <= b) and np.any(a < b))


def weakly_dominates(a, b) -> bool:
    """True iff ``a`` is no worse than ``b`` on every objective."""
    a, b = sanitize(a), sanitize(b)
    return bool(np.all(a <= b))


def pareto_front(objs) -> np.ndarray:
    """Indices (ascending) of the non-dominated rows of ``objs``.

    A row is kept unless some other row strictly dominates it;
    duplicate rows therefore all stay on the front (neither strictly
    dominates the other), keeping the selection deterministic under
    candidate reordering.
    """
    objs = sanitize(objs)
    n = objs.shape[0]
    keep = np.ones((n,), bool)
    for i in range(n):
        strict = np.all(objs <= objs[i], axis=1) & np.any(
            objs < objs[i], axis=1
        )
        keep[i] = not bool(np.any(strict))
    return np.flatnonzero(keep)


__all__ = ["sanitize", "dominates", "weakly_dominates", "pareto_front"]
