"""Gradient-free policy search: CEM with successive-halving rungs.

The driver is deliberately boring where it matters for reproducibility:

* all randomness flows from ONE ``jax.random.PRNGKey(seed)``, threaded
  per generation with ``fold_in`` — no ``time()``/global-RNG state;
* elite selection is pure numpy: ``np.lexsort`` over (score, index) —
  the index tie-break makes equal scores deterministic;
* every evaluation rebuilds its scenario batch from fixed seeds (the
  engine donates its input), so rung L of generation g sees bitwise the
  same lanes on every run, sharded or not.

Same seed ⇒ identical candidate history and Pareto front
(tests/test_search.py runs the whole driver twice and compares the
JSON artifacts byte-for-byte, and again across ``shard="auto"``).

The pure helpers (:func:`scalarize`, :func:`elite_select`,
:func:`halving_lane_counts`) are module-level precisely so the
property-test wall can check the CEM/halving invariants against
independent numpy oracles.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Callable, Sequence

import jax
import numpy as np

from repro.core.policy import PolicyParams
from repro.core.state import Workload

from .grid import OBJECTIVES, evaluate_policies
from .pareto import pareto_front, sanitize, weakly_dominates
from .space import PolicySpace

# scalarisation weights over OBJECTIVES (all minimised, utilisation
# included — see grid.OBJECTIVES for why): mean latency leads, p99 is
# a tail regulariser, utilisation and cost are the footprint terms.
# Latency is O(1e-2) s while utilisation is O(1e-1), so the footprint
# weights stay small to keep the latency term in charge of ranking.
DEFAULT_WEIGHTS = (1.0, 0.1, 0.01, 100.0)

# the acceptance-triple column indices: (mean latency, utilisation,
# cost_dollars) — what "weakly dominates every named baseline" means
DOMINANCE_COLUMNS = (0, 2, 3)


def scalarize(objectives, weights=DEFAULT_WEIGHTS) -> np.ndarray:
    """Weighted-sum scores (lower is better); any NaN/inf objective
    pushes the candidate's score to +inf (it can still appear in the
    history, it just never wins)."""
    objs = sanitize(objectives)
    w = np.asarray(weights, np.float64)
    if w.shape != (objs.shape[1],):
        raise ValueError(
            f"weights must match the {objs.shape[1]} objective columns"
        )
    scores = objs @ w
    return np.where(np.isfinite(scores), scores, np.inf)


def elite_select(scores, n_elite: int) -> np.ndarray:
    """Indices of the ``n_elite`` lowest scores, ties broken by index
    (``np.lexsort`` keys: score primary, position secondary)."""
    scores = np.asarray(scores, np.float64)
    if not 0 < n_elite <= scores.shape[0]:
        raise ValueError(
            f"n_elite must be in [1, {scores.shape[0]}], got {n_elite}"
        )
    order = np.lexsort((np.arange(scores.shape[0]), scores))
    return order[:n_elite]


def halving_lane_counts(n_lanes: int, rungs: Sequence[float]) -> list[int]:
    """Strictly-increasing rung lane counts from fractions; the last
    rung always evaluates the full batch.

    >>> halving_lane_counts(8, (0.25, 0.5, 1.0))
    [2, 4, 8]
    >>> halving_lane_counts(3, (0.5, 1.0))
    [2, 3]
    """
    counts: list[int] = []
    for f in rungs:
        if not 0.0 < f <= 1.0:
            raise ValueError(f"rung fractions must be in (0, 1], got {f}")
        c = max(1, int(round(f * n_lanes)))
        if not counts or c > counts[-1]:
            counts.append(c)
    if counts[-1] != n_lanes:
        counts.append(n_lanes)
    return counts


@dataclass
class SearchResult:
    """The recorded candidate-history artifact of one search run."""

    seed: int
    objectives: tuple[str, ...]
    history: list[dict]
    baseline_names: list[str]
    baseline_objectives: np.ndarray  # [B, 4]
    pareto_policies: np.ndarray      # [K, P] f32
    pareto_objectives: np.ndarray    # [K, 4]
    champion: dict | None = None
    evaluations: int = 0
    meta: dict = field(default_factory=dict)

    def to_json(self) -> str:
        """Canonical JSON (sorted keys) — byte-identical across runs of
        the same seed; the determinism tests diff this string."""
        payload = {
            "seed": self.seed,
            "objectives": list(self.objectives),
            "history": self.history,
            "baselines": {
                name: [float(v) for v in row]
                for name, row in zip(
                    self.baseline_names, self.baseline_objectives
                )
            },
            "pareto_policies": self.pareto_policies.tolist(),
            "pareto_objectives": self.pareto_objectives.tolist(),
            "champion": self.champion,
            "evaluations": self.evaluations,
            "meta": self.meta,
        }
        return json.dumps(payload, sort_keys=True)


def _as_float_rows(a) -> list[list[float]]:
    return [[float(v) for v in row] for row in np.asarray(a)]


def cem_search(
    make_scenarios: Callable[[], tuple[Workload, "object"]],
    *,
    seed: int = 0,
    generations: int = 4,
    population: int = 16,
    elite_frac: float = 0.25,
    rungs: Sequence[float] = (0.5, 1.0),
    weights: Sequence[float] = DEFAULT_WEIGHTS,
    baselines: dict[str, PolicyParams] | None = None,
    space: PolicySpace | None = None,
    init_std: float = 0.25,
    std_floor: float = 0.02,
    shard: str | int | None = None,
) -> SearchResult:
    """Cross-entropy search over the policy space (see module docs).

    Each generation's candidate block is ``baselines + previous elites
    + Gaussian samples`` (uniform at generation 0), evaluated through
    successive-halving rungs: everyone runs the cheapest lane prefix,
    the top half advances, until the survivors run the full scenario
    batch. Elites refit the Gaussian; the elitist carryover means the
    per-generation best full-fidelity score is monotone non-increasing
    (a tested invariant). ``baselines`` defaults to every registered
    named-scheduler point (``scheduler.policy_points()``), evaluated
    once at full fidelity as the comparison row the Pareto front is
    judged against.
    """
    from repro.core.scheduler import policy_points

    if baselines is None:
        baselines = policy_points()
    base_names = sorted(baselines)
    space = space or PolicySpace()
    B = len(base_names)
    n_elite = max(1, int(round(elite_frac * population)))
    if population < B + n_elite + 1:
        raise ValueError(
            f"population={population} too small for {B} baselines + "
            f"{n_elite} elites + 1 sample"
        )

    wls_probe, _ = make_scenarios()
    S = int(wls_probe.arrival.shape[0])
    del wls_probe
    lane_counts = halving_lane_counts(S, rungs)

    base_vecs = space.normalize(
        np.stack([baselines[n].to_vector() for n in base_names])
    ) if B else np.zeros((0, len(space.names)), np.float32)

    key = jax.random.PRNGKey(seed)
    mean = np.full((len(space.names),), 0.5, np.float32)
    std = np.full((len(space.names),), np.float32(init_std), np.float32)

    history: list[dict] = []
    pool_pol: list[np.ndarray] = []   # full-fidelity evaluations
    pool_obj: list[np.ndarray] = []
    pool_tag: list[str] = []
    evaluations = 0
    elites_u = np.zeros((0, len(space.names)), np.float32)
    best_score = np.inf

    # baselines once, at full fidelity — the judgement row
    if B:
        res = evaluate_policies(
            make_scenarios, space.denormalize(base_vecs), shard=shard
        )
        evaluations += res["C"] * res["S"]
        baseline_objs = res["objectives"]
        for name, u, obj in zip(base_names, base_vecs, baseline_objs):
            pool_pol.append(space.denormalize(u))
            pool_obj.append(obj)
            pool_tag.append(f"baseline:{name}")
    else:
        baseline_objs = np.zeros((0, len(OBJECTIVES)))

    for gen in range(generations):
        kgen = jax.random.fold_in(key, gen)
        E = elites_u.shape[0]
        n_sample = population - B - E
        if gen == 0:
            samples = space.sample_uniform(kgen, n_sample)
        else:
            samples = space.sample_gaussian(kgen, mean, std, n_sample)
        unit = np.concatenate([base_vecs, elites_u, samples], axis=0)
        origin = (
            [f"baseline:{n}" for n in base_names]
            + ["elite"] * E
            + ["sample"] * n_sample
        )
        pols = space.denormalize(unit)

        alive = np.arange(population)
        rung_log: list[dict] = []
        scores = None
        objs = None
        for L in lane_counts:
            res = evaluate_policies(
                make_scenarios,
                pols[alive],
                lane_limit=None if L == S else L,
                shard=shard,
            )
            evaluations += res["C"] * res["S"]
            objs = res["objectives"]
            scores = scalarize(objs, weights)
            rung_log.append(
                {
                    "lanes": L,
                    "candidates": [int(i) for i in alive],
                    "scores": [float(s) for s in scores],
                    "objectives": _as_float_rows(objs),
                }
            )
            if L != lane_counts[-1]:
                keep_n = max(n_elite, -(-len(alive) // 2))
                # carried-over elites are exempt from low-fidelity cuts:
                # they always reach the full batch, which is what makes
                # the per-generation best score monotone (their full-
                # fidelity scores are deterministic re-evaluations)
                prot = np.flatnonzero((alive >= B) & (alive < B + E))
                rest = np.flatnonzero((alive < B) | (alive >= B + E))
                n_rest = keep_n - prot.size
                chosen = (
                    rest[elite_select(scores[rest], n_rest)]
                    if n_rest > 0 and rest.size
                    else np.zeros((0,), np.int64)
                )
                alive = alive[np.sort(np.concatenate([prot, chosen]))]

        # full-fidelity survivors feed the front and the elite refit
        for i, idx in enumerate(alive):
            pool_pol.append(pols[idx])
            pool_obj.append(objs[i])
            pool_tag.append(f"gen{gen}:{origin[idx]}")
        elite_local = elite_select(scores, min(n_elite, len(alive)))
        elite_idx = alive[elite_local]
        elites_u = unit[elite_idx]
        gen_best = float(np.min(scores))
        best_score = min(best_score, gen_best)
        mean = elites_u.mean(axis=0).astype(np.float32)
        std = np.maximum(
            elites_u.std(axis=0), np.float32(std_floor)
        ).astype(np.float32)

        history.append(
            {
                "generation": gen,
                "policies": _as_float_rows(pols),
                "origin": origin,
                "rungs": rung_log,
                "survivors": [int(i) for i in alive],
                "elites": [int(i) for i in elite_idx],
                "best_score": gen_best,
                "mean": [float(v) for v in mean],
                "std": [float(v) for v in std],
            }
        )

    pool_obj_arr = np.stack(pool_obj) if pool_obj else np.zeros((0, 4))
    pool_pol_arr = (
        np.stack(pool_pol)
        if pool_pol
        else np.zeros((0, len(space.names)), np.float32)
    )
    front = pareto_front(pool_obj_arr)
    champion = None
    tri = pool_obj_arr[:, list(DOMINANCE_COLUMNS)]
    base_tri = baseline_objs[:, list(DOMINANCE_COLUMNS)] if B else None
    eligible = [
        i for i in front
        if base_tri is not None
        and all(weakly_dominates(tri[i], b) for b in base_tri)
    ]
    if eligible:
        # of the eligible front members, crown the best-scoring one —
        # pool order lists baselines first, so "first eligible" would
        # shadow a searched strict improvement with the baseline point
        # it improves on (elite_select tie-breaks equal scores by pool
        # position, keeping the pick deterministic)
        pool_scores = scalarize(pool_obj_arr, weights)
        i = int(
            np.asarray(eligible)[elite_select(pool_scores[eligible], 1)][0]
        )
        champion = {
            "policy": [float(v) for v in pool_pol_arr[i]],
            "objectives": [float(v) for v in pool_obj_arr[i]],
            "origin": pool_tag[i],
        }

    return SearchResult(
        seed=seed,
        objectives=OBJECTIVES,
        history=history,
        baseline_names=base_names,
        baseline_objectives=baseline_objs,
        pareto_policies=pool_pol_arr[front],
        pareto_objectives=pool_obj_arr[front],
        champion=champion,
        evaluations=evaluations,
        meta={
            "generations": generations,
            "population": population,
            "elite_frac": elite_frac,
            "rungs": list(rungs),
            "weights": [float(w) for w in weights],
            "lane_counts": lane_counts,
            "scenario_lanes": S,
        },
    )


__all__ = [
    "DEFAULT_WEIGHTS",
    "DOMINANCE_COLUMNS",
    "SearchResult",
    "cem_search",
    "elite_select",
    "halving_lane_counts",
    "scalarize",
]
