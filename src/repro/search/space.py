"""The normalised policy search space over
:class:`~repro.core.policy.PolicyParams`.

Searches live in the unit cube ``[0, 1]^P`` and map through the
per-knob ``POLICY_BOUNDS`` box; every named scheduler's default point
normalises into the cube, so populations can be seeded from (and
compared against) the built-ins. All sampling takes an explicit
``jax.random`` key — no hidden RNG state anywhere in the search stack.

>>> import jax, numpy as np
>>> from repro.core.policy import DEFAULT_POINTS
>>> sp = PolicySpace()
>>> u = sp.normalize(DEFAULT_POINTS["sjf"].to_vector())
>>> bool((u >= 0).all() and (u <= 1).all())
True
>>> np.allclose(sp.denormalize(u), DEFAULT_POINTS["sjf"].to_vector())
True
>>> sp.sample_uniform(jax.random.PRNGKey(0), 4).shape
(4, 15)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import N_POLICY_PARAMS, PolicyParams, policy_bounds


class PolicySpace:
    """Box-bounded policy space with unit-cube sampling helpers.

    ``lo``/``hi`` default to :func:`repro.core.policy.policy_bounds`;
    pass narrower vectors to restrict a search (e.g. pin the naive-mode
    switches to 0 by setting ``lo = hi`` on those axes).
    """

    def __init__(self, lo=None, hi=None):
        d_lo, d_hi = policy_bounds()
        self.lo = np.asarray(d_lo if lo is None else lo, np.float32)
        self.hi = np.asarray(d_hi if hi is None else hi, np.float32)
        if self.lo.shape != (N_POLICY_PARAMS,) or self.hi.shape != (
            N_POLICY_PARAMS,
        ):
            raise ValueError(
                f"bounds must be [{N_POLICY_PARAMS}] vectors, got "
                f"{self.lo.shape} / {self.hi.shape}"
            )
        if np.any(self.hi < self.lo):
            raise ValueError("hi < lo on some axis")
        self.names = PolicyParams._fields

    # -- unit-cube <-> knob space -----------------------------------------
    def denormalize(self, u) -> np.ndarray:
        """Map ``[..., P]`` unit-cube points to policy vectors (f32)."""
        u = np.asarray(u, np.float32)
        return (self.lo + u * (self.hi - self.lo)).astype(np.float32)

    def normalize(self, x) -> np.ndarray:
        """Map policy vectors into the unit cube (degenerate axes with
        ``hi == lo`` map to 0)."""
        x = np.asarray(x, np.float32)
        span = self.hi - self.lo
        return np.where(
            span > 0, (x - self.lo) / np.maximum(span, 1e-12), 0.0
        ).astype(np.float32)

    # -- threaded-key sampling (normalised space) --------------------------
    def sample_uniform(self, key, n: int) -> np.ndarray:
        """``[n, P]`` uniform unit-cube sample from an explicit key."""
        u = jax.random.uniform(key, (n, N_POLICY_PARAMS), jnp.float32)
        return np.asarray(u)

    def sample_gaussian(self, key, mean, std, n: int) -> np.ndarray:
        """``[n, P]`` Gaussian sample around ``mean``/``std`` (unit-cube
        coordinates), clipped back into the cube — the CEM proposal."""
        mean = jnp.asarray(mean, jnp.float32)
        std = jnp.asarray(std, jnp.float32)
        z = jax.random.normal(key, (n, N_POLICY_PARAMS), jnp.float32)
        return np.asarray(jnp.clip(mean + z * std, 0.0, 1.0))


__all__ = ["PolicySpace"]
