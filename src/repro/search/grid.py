"""Vmapped policy-grid evaluation: candidates × scenarios in one fleet.

``evaluate_policies`` is the search's oracle call. It tiles a scenario
batch across a candidate grid (``sweep.policy_grid_workloads``), runs
ONE ``fleet_run`` under the dynamic ``"policy"`` scheduler family —
sharded and lane-binned like any other fleet, per-lane bitwise-
deterministic whatever the sharding — and reduces per-lane statistics
(``metrics.fleet_lane_stats``) to one objective vector per candidate.

Donation contract: ``fleet_run`` consumes its workload batch, so the
caller passes a ``make_scenarios`` *factory* that rebuilds the batch
(bitwise, from fixed seeds) on every call; arrival tables are copied to
host before the engine sees them.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import numpy as np

from repro.core.metrics import fleet_lane_stats
from repro.core.params import SimParams
from repro.core.state import Workload
from repro.core.sweep import fleet_run, policy_grid_workloads

# objective columns, all minimised — the Pareto front and the CEM
# scalarisation both rank over this layout. Two deliberate choices:
# latency is the CENSORED estimator (every arrived pipeline counts,
# unfinished ones at their `horizon - arrival` lower bound — see
# ``metrics.fleet_lane_stats``), so a policy can't shine by stranding
# the queue and reporting the latency of the two pipelines it deigned
# to finish; and utilisation is minimised too, because the scenario
# batch fixes the work — CPU-seconds above the workload's intrinsic
# demand are waste (retry re-work, preemption restarts, premium cloud
# overflow), and in a pay-per-use FaaS setting the operator wants the
# same pipelines finished sooner on a smaller resource footprint.
OBJECTIVES = (
    "censored_mean_latency_s",
    "censored_p99_latency_s",
    "cpu_utilization",
    "cost_dollars",
)


def _nanmean_cols(x: np.ndarray) -> np.ndarray:
    """Row-wise nanmean without the all-NaN RuntimeWarning; all-NaN
    rows stay NaN (sanitised to +inf at ranking time)."""
    finite = np.isfinite(x)
    cnt = finite.sum(axis=1)
    tot = np.where(finite, x, 0.0).sum(axis=1)
    return np.where(cnt > 0, tot / np.maximum(cnt, 1), np.nan)


def evaluate_policies(
    make_scenarios: Callable[[], tuple[Workload, SimParams]],
    policies,
    *,
    lane_limit: int | None = None,
    shard: str | int | None = None,
) -> dict:
    """Evaluate a ``[C, P]`` policy grid over a scenario batch.

    ``make_scenarios`` returns ``(workloads, params)`` (e.g. a
    ``scenario_fleet`` closure) and is called once per evaluation — the
    batch is consumed by the engine. ``lane_limit`` keeps only the
    first L scenario lanes (successive-halving rungs evaluate cheap
    low-fidelity prefixes of the same batch).

    Returns ``{"objectives": [C, 4], "per_candidate": {stat: [C]},
    "C": C, "S": S}`` with objective columns :data:`OBJECTIVES`;
    candidates whose every lane finished nothing get NaN latency
    objectives (never an exception).
    """
    wls, params = make_scenarios()
    if wls.policy is not None:
        raise ValueError(
            "make_scenarios must return a policy-free batch; "
            "evaluate_policies attaches the candidate grid itself"
        )
    if lane_limit is not None:
        if lane_limit <= 0:
            raise ValueError(f"lane_limit must be positive, got {lane_limit}")
        wls = jax.tree.map(lambda x: x[:lane_limit], wls)
    grid, C, S = policy_grid_workloads(wls, policies)
    # host copies BEFORE the engine donates (consumes) the batch
    arrival = np.asarray(grid.arrival)
    states = fleet_run(
        params.replace(scheduling_algo="policy"),
        workloads=grid,
        shard=shard,
    )
    lanes = fleet_lane_stats(states, params, arrival=arrival)

    per_candidate = {
        name: _nanmean_cols(
            np.asarray(col, np.float64).reshape(C, S)
        )
        for name, col in lanes.items()
    }
    objectives = np.stack(
        [per_candidate[name] for name in OBJECTIVES], axis=1
    )
    return {
        "objectives": objectives,
        "per_candidate": per_candidate,
        "C": C,
        "S": S,
    }


def scenario_factory(
    names: Sequence[str] | str,
    params: SimParams,
    n_lanes: int,
    *,
    seed: int = 0,
    **knobs,
) -> Callable[[], tuple[Workload, SimParams]]:
    """A ``make_scenarios`` closure over the scenario library.

    Each call rebuilds the same batch bitwise (fixed ``seed``), which is
    exactly what the donation contract needs; with a list of names the
    lanes round-robin the families (``scenario_fleet``).
    """
    from repro.core.scenarios import scenario_fleet

    names = [names] if isinstance(names, str) else list(names)

    def make() -> tuple[Workload, SimParams]:
        return scenario_fleet(names, params, n_lanes, seed=seed, **knobs)

    return make


__all__ = ["OBJECTIVES", "evaluate_policies", "scenario_factory"]
