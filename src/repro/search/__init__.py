"""Fleet-scale scheduling-policy search on top of the fused engine.

The paper frames Eudoxia as "a cheap mechanism for developers to
evaluate different scheduling algorithms"; the Bauplan follow-up
(PAPERS.md) closes the loop by *searching* policy space with the
simulator as the oracle. This package is that loop:

* :mod:`repro.search.space` — the normalised policy box
  (:class:`~repro.core.policy.PolicyParams` bounds) with threaded-key
  sampling;
* :mod:`repro.search.pareto` — NaN-guarded dominance and Pareto fronts;
* :mod:`repro.search.grid` — one ``fleet_run`` per evaluation: the
  fleet axis spans policy candidates × scenario lanes (vmapped, device-
  sharded, lane-binned like any other fleet);
* :mod:`repro.search.driver` — a gradient-free CEM driver with
  successive-halving rungs, pure-numpy elite selection, and a recorded
  candidate-history artifact.

Reproducibility contract (docs/policy-search.md): all randomness flows
from one ``jax.random.PRNGKey(seed)`` threaded by ``fold_in``; scenario
batches are rebuilt bitwise-identically from fixed seeds per rung
(``fleet_run`` donates its input); elite selection is ``np.lexsort``
with an index tie-break. Same seed ⇒ identical candidate history and
Pareto front, on or off device sharding.
"""
from .driver import (
    SearchResult,
    cem_search,
    elite_select,
    halving_lane_counts,
    scalarize,
)
from .grid import OBJECTIVES, evaluate_policies, scenario_factory
from .pareto import dominates, pareto_front, sanitize, weakly_dominates
from .space import PolicySpace

__all__ = [
    "OBJECTIVES",
    "PolicySpace",
    "SearchResult",
    "cem_search",
    "dominates",
    "elite_select",
    "evaluate_policies",
    "halving_lane_counts",
    "pareto_front",
    "sanitize",
    "scalarize",
    "scenario_factory",
    "weakly_dominates",
]
