"""Serving driver: Eudoxia-evaluated policy + continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_7b \
        --requests 12 --slots 4

1. Builds a synthetic request trace (mixed interactive/batch).
2. Replays it in the Eudoxia simulator under each candidate scheduling
   policy (paper §4) and picks the winner.
3. Serves the trace for real through the continuous batcher (smoke
   config) with that policy.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs.registry import get_arch
from repro.models import lm
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.bridge import ServeRequest, evaluate_policies, pick_policy


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6_7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.smoke
    rng = np.random.default_rng(args.seed)

    # ---- 1. synthetic trace --------------------------------------------
    trace = [
        ServeRequest(
            arrival_s=float(rng.exponential(0.3) * i),
            prompt_tokens=int(rng.integers(8, 24)),
            new_tokens=args.max_new,
            interactive=bool(rng.random() < 0.4),
        )
        for i in range(args.requests)
    ]

    # ---- 2. policy evaluation in the simulator ---------------------------
    sim = evaluate_policies(trace, arch.model, duration_s=30.0)
    policy = pick_policy(sim)
    print("simulator policy comparison:")
    for name, s in sim.items():
        inter = s["per_priority"]["interactive"]
        print(
            f"  {name:14s} thr={s['throughput_per_s']:7.2f}/s "
            f"inter_lat={inter['mean_latency_s']!s:>10} "
            f"pre={s['preempt_events']} oom={s['oom_events']}"
        )
    print(f"-> selected policy: {policy}")

    # ---- 3. real serving under the chosen policy -------------------------
    params, _ = lm.lm_init(cfg, jax.random.PRNGKey(0)) if cfg.family != "audio" else (None, None)
    if params is None:
        raise SystemExit("serve demo supports decoder-only archs")
    batcher = ContinuousBatcher(
        cfg, params, slots=args.slots, max_len=64, policy=policy
    )
    for i, r in enumerate(trace):
        toks = rng.integers(2, cfg.vocab, size=r.prompt_tokens).astype(np.int32)
        batcher.submit(
            Request(rid=i, tokens=toks, max_new=r.new_tokens,
                    interactive=r.interactive)
        )
    done = batcher.run_to_completion()
    print(
        json.dumps(
            {
                "served": len(done),
                "policy": policy,
                "sample_output_lens": [len(r.out) for r in done[:8]],
            }
        )
    )


if __name__ == "__main__":
    main()
