"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3_12b \
        --steps 200 --smoke --ckpt-dir /tmp/ckpt --ckpt-every 50

On this CPU container: run the reduced (smoke) config of any assigned
architecture for a few hundred steps with checkpoints, failure
injection and straggler monitoring. On a real pod the same driver takes
--no-smoke plus the production mesh.
"""
from __future__ import annotations

import argparse
import json


from repro.configs.registry import get_arch
from repro.launch.mesh import make_host_mesh
from repro.runtime.failures import FailureInjector
from repro.runtime.train_loop import run_training


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--inject-failures", action="store_true")
    ap.add_argument("--mesh-data", type=int, default=0,
                    help="use a (data,model) host mesh of this data size")
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    mesh = None
    if args.mesh_data:
        mesh = make_host_mesh(data=args.mesh_data, model=args.mesh_model)

    injector = (
        FailureInjector(mtbf_steps=args.steps / 3, max_failures=2)
        if args.inject_failures
        else None
    )
    result = run_training(
        arch,
        steps=args.steps,
        mesh=mesh,
        use_smoke_config=args.smoke,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        injector=injector,
        microbatches=args.microbatches,
        on_metrics=lambda s, m: (
            print(f"step {s:5d} loss {m['loss']:.4f} ({m['dt']*1e3:.0f} ms)")
            if s % 10 == 0
            else None
        ),
    )
    print(
        json.dumps(
            {
                "arch": args.arch,
                "steps_done": result.steps_done,
                "first_loss": result.losses[0] if result.losses else None,
                "last_loss": result.losses[-1] if result.losses else None,
                "restarts": result.restarts,
                "straggler_events": result.straggler_events,
            },
            indent=1,
        )
    )


if __name__ == "__main__":
    main()
