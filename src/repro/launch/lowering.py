"""Build abstract (no-allocation) lowerings of train/prefill/decode steps
for any (arch x shape x mesh) cell. Shared by dryrun.py, tests and the
roofline benchmarks."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchSpec
from repro.launch.shapes import (
    SHAPES,
    batch_axes,
    batch_specs,
    cache_axes,
    cache_shapes,
    opt_axes,
)
from repro.models.common import ModelConfig
from repro.optim.optimizers import OptConfig
from repro.parallel.ctx import sharding_ctx
from repro.parallel.sharding import ShardingRules, spec_for
from repro.runtime.steps import (
    TrainState,
    make_serve_steps,
    make_train_step,
    model_init,
)

S = jax.ShapeDtypeStruct


def arch_rules(arch: ArchSpec) -> ShardingRules:
    return ShardingRules().override(
        param=arch.rule_overrides.get("param"),
        act=arch.rule_overrides.get("act"),
    )


def model_axes_and_shapes(cfg: ModelConfig):
    """(axes_tree, param_shape_tree) without allocating parameters."""
    box: dict[str, Any] = {}

    def f(key):
        params, axes = model_init(cfg, key)
        box["axes"] = axes
        return params

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["axes"], shapes


def shardings_of(axes_tree, shape_tree, mesh: Mesh, rules) -> Any:
    return jax.tree.map(
        lambda ax, sh: NamedSharding(mesh, spec_for(sh.shape, ax, mesh, rules)),
        axes_tree,
        shape_tree,
    )


def opt_config(arch: ArchSpec) -> OptConfig:
    return OptConfig(
        name=arch.optimizer,
        state_dtype=jnp.bfloat16
        if arch.opt_state_dtype == "bfloat16"
        else jnp.float32,
    )


def lower_train(arch: ArchSpec, shape_name: str, mesh: Mesh):
    cfg = arch.model
    rules = arch_rules(arch)
    shape = SHAPES[shape_name]
    ocfg = opt_config(arch)
    init_fn, step_fn = make_train_step(
        cfg, ocfg, microbatches=arch.train_microbatches
    )

    # ---- abstract state + shardings ------------------------------------
    p_axes, p_shapes = model_axes_and_shapes(cfg)
    state_shapes = jax.eval_shape(lambda k: init_fn(k)[0], jax.random.PRNGKey(0))
    o_axes = opt_axes(arch.optimizer, p_axes, p_shapes)
    state_axes = TrainState(params=p_axes, opt=o_axes)
    state_sh = shardings_of(state_axes, state_shapes, mesh, rules.param)

    b_shapes = batch_specs(cfg, shape)
    b_axes = batch_axes(cfg, shape)
    b_sh = shardings_of(b_axes, b_shapes, mesh, rules.act)

    repl = NamedSharding(mesh, P())
    metrics_sh = {"loss": repl, "grad_norm": repl, "step": repl}

    with mesh, sharding_ctx(mesh, rules.act):
        lowered = jax.jit(
            step_fn,
            in_shardings=(state_sh, b_sh),
            out_shardings=(state_sh, metrics_sh),
            donate_argnums=(0,),
        ).lower(state_shapes, b_shapes)
    return lowered


def lower_prefill(arch: ArchSpec, shape_name: str, mesh: Mesh):
    cfg = arch.model
    rules = arch_rules(arch)
    shape = SHAPES[shape_name]
    prefill_fn, _ = make_serve_steps(cfg)

    p_axes, p_shapes = model_axes_and_shapes(cfg)
    p_sh = shardings_of(p_axes, p_shapes, mesh, rules.param)
    b_shapes = batch_specs(cfg, shape)
    b_sh = shardings_of(batch_axes(cfg, shape), b_shapes, mesh, rules.act)

    c_axes = cache_axes(cfg)
    c_shapes = cache_shapes(cfg, shape.batch, shape.seq)
    c_sh = shardings_of(c_axes, c_shapes, mesh, rules.act)
    logits_sh = NamedSharding(
        mesh, spec_for((shape.batch, cfg.vocab), "batch vocab", mesh, rules.act)
    )

    with mesh, sharding_ctx(mesh, rules.act):
        lowered = jax.jit(
            functools.partial(prefill_fn, max_len=shape.seq),
            in_shardings=(p_sh, b_sh),
            out_shardings=(logits_sh, c_sh),
        ).lower(p_shapes, b_shapes)
    return lowered


def lower_decode(arch: ArchSpec, shape_name: str, mesh: Mesh):
    cfg = arch.model
    rules = arch_rules(arch)
    shape = SHAPES[shape_name]
    _, decode_fn = make_serve_steps(cfg)

    p_axes, p_shapes = model_axes_and_shapes(cfg)
    p_sh = shardings_of(p_axes, p_shapes, mesh, rules.param)
    c_axes = cache_axes(cfg)
    c_shapes = cache_shapes(cfg, shape.batch, shape.seq)
    c_sh = shardings_of(c_axes, c_shapes, mesh, rules.act)

    tok_shape = S((shape.batch,), jnp.int32)
    tok_sh = NamedSharding(
        mesh, spec_for((shape.batch,), "batch", mesh, rules.act)
    )
    pos_shape = S((), jnp.int32)
    repl = NamedSharding(mesh, P())
    logits_sh = NamedSharding(
        mesh, spec_for((shape.batch, cfg.vocab), "batch vocab", mesh, rules.act)
    )

    with mesh, sharding_ctx(mesh, rules.act):
        lowered = jax.jit(
            decode_fn,
            in_shardings=(p_sh, c_sh, tok_sh, repl),
            out_shardings=(logits_sh, c_sh),
            donate_argnums=(1,),
        ).lower(p_shapes, c_shapes, tok_shape, pos_shape)
    return lowered


def lower_cell(arch: ArchSpec, shape_name: str, mesh: Mesh):
    kind = SHAPES[shape_name].kind
    if kind == "train":
        return lower_train(arch, shape_name, mesh)
    if kind == "prefill":
        return lower_prefill(arch, shape_name, mesh)
    return lower_decode(arch, shape_name, mesh)


__all__ = [
    "arch_rules",
    "model_axes_and_shapes",
    "shardings_of",
    "opt_config",
    "lower_train",
    "lower_prefill",
    "lower_decode",
    "lower_cell",
]
