"""Assigned input shapes + abstract input specs per (arch x shape).

Shapes (LM transformer: seq_len x global_batch):
    train_4k     seq=4096    batch=256   -> train_step
    prefill_32k  seq=32768   batch=32    -> prefill
    decode_32k   seq=32768   batch=128   -> serve_step (1 token, KV=seq)
    long_500k    seq=524288  batch=1     -> serve_step (sub-quadratic only)

``input_specs()`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation). Axes helpers build the logical-axis
trees for caches and optimizer state so the dry-run can construct full
in/out shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.common import ModelConfig
from repro.models.encdec import dec_len


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

S = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """Abstract model inputs for train/prefill of one global batch."""
    B, L = shape.batch, shape.seq
    if cfg.family == "audio":
        return {
            "frontend_embeds": S((B, L, lm.VIT_DIM), jnp.bfloat16),
            "tokens": S((B, dec_len(cfg, L)), jnp.int32),
        }
    out = {"tokens": S((B, L), jnp.int32)}
    if cfg.family == "vlm":
        out["frontend_embeds"] = S((B, cfg.n_img_tokens, lm.VIT_DIM), jnp.bfloat16)
    return out


def batch_axes(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, str]:
    if cfg.family == "audio":
        return {"frontend_embeds": "batch seq state", "tokens": "batch seq"}
    out = {"tokens": "batch seq"}
    if cfg.family == "vlm":
        out["frontend_embeds"] = "batch seq state"
    return out


# ---------------------------------------------------------------------------
# cache axes (mirror lm.init_caches / encdec caches structure)
# ---------------------------------------------------------------------------
def _block_cache_axes(cfg: ModelConfig, kind: str, stacked: bool):
    pre = "layers " if stacked else ""
    if kind == "attn":
        from repro.models.attention import KVCache

        ax = f"{pre}batch kv_seq kv_heads head_dim"
        return KVCache(k=ax, v=ax)
    if kind == "mamba":
        from repro.models.ssm import MambaState

        return MambaState(
            h=f"{pre}batch ff state", conv=f"{pre}batch conv ff"
        )
    if kind == "rwkv":
        from repro.models.rwkv import RWKVState

        return RWKVState(
            wkv=f"{pre}batch heads head_dim state",
            shift_t=f"{pre}batch seq embed",
            shift_c=f"{pre}batch seq embed",
        )
    raise ValueError(kind)


def cache_axes(cfg: ModelConfig):
    if cfg.family == "audio":
        from repro.models.encdec import EncDecCaches
        from repro.models.attention import KVCache

        ax = "layers batch kv_seq kv_heads head_dim"
        return EncDecCaches(
            self_kv=KVCache(k=ax, v=ax), cross_kv=(ax, ax)
        )
    return {
        "periods": [
            _block_cache_axes(cfg, spec.kind, stacked=True)
            for spec in cfg.pattern
        ],
        "tail": [
            _block_cache_axes(
                cfg, cfg.pattern[t % cfg.period].kind, stacked=False
            )
            for t in range(cfg.n_tail)
        ],
    }


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int):
    """Abstract cache tree (no allocation)."""
    if cfg.family == "audio":
        from repro.models.attention import KVCache
        from repro.models.encdec import EncDecCaches, dec_len as _dl

        L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
        d_dec = _dl(cfg, max_len)
        kv = lambda s: KVCache(
            k=S((L, batch, s, KV, hd), cfg.compute_dtype),
            v=S((L, batch, s, KV, hd), cfg.compute_dtype),
        )
        return EncDecCaches(
            self_kv=kv(d_dec),
            cross_kv=(
                S((L, batch, max_len, KV, hd), cfg.compute_dtype),
                S((L, batch, max_len, KV, hd), cfg.compute_dtype),
            ),
        )
    return jax.eval_shape(lambda: lm.init_caches(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# optimizer state axes (mirror optim state structure over param axes)
# ---------------------------------------------------------------------------
def opt_axes(opt_name: str, param_axes, param_shapes):
    from repro.optim.optimizers import OptState, _factored

    if opt_name == "adamw":
        return OptState(step="", inner={"m": param_axes, "v": param_axes})

    def v_axes(ax: str, shape):
        names = ax.split()
        if _factored(shape.shape):
            return {
                "vr": " ".join(names[:-1]),
                "vc": " ".join(names[:-2] + names[-1:]),
            }
        return {"v": ax}

    inner = jax.tree.map(v_axes, param_axes, param_shapes)
    return OptState(step="", inner=inner)


__all__ = [
    "SHAPES",
    "ShapeSpec",
    "batch_specs",
    "batch_axes",
    "cache_axes",
    "cache_shapes",
    "opt_axes",
]
