import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import: jax locks the device
count on first init, and the production meshes need 512 placeholder
devices on this CPU-only container.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3_12b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all \
        --report reports/dryrun.json

Per cell this prints/records compiled.memory_analysis() (proves the
programme fits 16 GB/chip) and compiled.cost_analysis() + parsed
collective bytes (feeds EXPERIMENTS.md §Roofline).
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.registry import get_arch, list_archs
from repro.launch.lowering import lower_cell, model_axes_and_shapes
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES
from repro.roofline.analysis import Roofline, model_flops_estimate
from repro.roofline.hlo_stats import analyze_hlo
from repro.roofline.hw import HBM_BYTES


def run_cell(arch_name: str, shape_name: str, mesh_kind: str) -> dict:
    arch = get_arch(arch_name)
    if shape_name in arch.skip:
        return {
            "arch": arch_name,
            "shape": shape_name,
            "mesh": mesh_kind,
            "status": "skipped",
            "reason": arch.skip[shape_name],
        }
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.devices.size

    t0 = time.time()
    lowered = lower_cell(arch, shape_name, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
    }
    # peak live bytes per device: args + temps (aliased args are donated)
    peak = (
        mem_d["argument_bytes"] + mem_d["temp_bytes"] - mem_d["alias_bytes"]
        + mem_d["output_bytes"]
    )
    cost = compiled.cost_analysis() or {}

    # loop-aware static analysis of the post-SPMD HLO (cost_analysis
    # counts while bodies once — useless for period-scanned stacks)
    hlo = compiled.as_text()
    stats = analyze_hlo(hlo, chips=chips)
    coll = {k: v for k, v in stats.coll.items()}
    coll["_counts"] = stats.coll_counts

    _, p_shapes = model_axes_and_shapes(arch.model)
    n_params = sum(x.size for x in jax.tree.leaves(p_shapes))
    mf = model_flops_estimate(arch, shape_name, n_params)

    # minimal per-device HBM traffic: weights + (decode) caches + batch,
    # each touched once — the lower bound for the memory term
    param_bytes = sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(p_shapes)
    )
    min_bytes = param_bytes / chips
    kind = SHAPES[shape_name].kind
    if kind == "decode":
        from repro.launch.shapes import cache_shapes

        cs = cache_shapes(arch.model, SHAPES[shape_name].batch,
                          SHAPES[shape_name].seq)
        min_bytes += sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(cs)
        ) / chips

    roof = Roofline(
        arch=arch_name,
        shape=shape_name,
        mesh=mesh_kind,
        chips=chips,
        flops_per_device=stats.flops,
        bytes_per_device=stats.bytes,
        collective_bytes_per_device=stats.coll_bytes,
        collectives=coll,
        model_flops=mf,
        memory_per_device=mem_d,
    )
    rec = roof.to_dict()
    rec.update(
        status="ok",
        n_params=n_params,
        t_lower_s=round(t_lower, 2),
        t_compile_s=round(t_compile, 2),
        peak_bytes_per_device=peak,
        fits_hbm=bool(peak <= HBM_BYTES),
        hlo_bytes=len(hlo),
        cost_analysis_flops=float(cost.get("flops", 0.0)),
        cost_analysis_bytes=float(cost.get("bytes accessed", 0.0)),
        min_bytes_per_device=min_bytes,
        mem_efficiency=min_bytes / max(stats.bytes, 1.0),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--report", default=None)
    ap.add_argument("--append", action="store_true")
    args = ap.parse_args()

    cells: list[tuple[str, str, str]] = []
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        for a in list_archs():
            for s in get_arch(a).shapes:
                for m in meshes:
                    cells.append((a, s, m))
    else:
        assert args.arch and args.shape
        for m in meshes:
            cells.append((args.arch, args.shape, m))

    results = []
    report_path = pathlib.Path(args.report) if args.report else None
    if report_path and args.append and report_path.exists():
        results = json.loads(report_path.read_text())
        done = {(r["arch"], r["shape"], r["mesh"]) for r in results}
        cells = [c for c in cells if c not in done]

    for a, s, m in cells:
        print(f"=== {a} x {s} x {m} ===", flush=True)
        try:
            rec = run_cell(a, s, m)
        except Exception as e:  # noqa: BLE001 — record and continue
            rec = {
                "arch": a,
                "shape": s,
                "mesh": m,
                "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:],
            }
        if rec["status"] == "ok":
            print(
                f"  compiled in {rec['t_compile_s']}s | "
                f"peak/device {rec['peak_bytes_per_device']/2**30:.2f} GiB "
                f"(fits={rec['fits_hbm']}) | "
                f"t_comp {rec['t_compute_s']*1e3:.2f} ms "
                f"t_mem {rec['t_memory_s']*1e3:.2f} ms "
                f"t_coll {rec['t_collective_s']*1e3:.2f} ms "
                f"-> {rec['dominant']}-bound | "
                f"useful {rec['useful_flops_fraction']*100:.0f}% "
                f"roofline {rec['roofline_fraction']*100:.0f}%",
                flush=True,
            )
        else:
            print(f"  {rec['status']}: {rec.get('reason', rec.get('error'))}",
                  flush=True)
        results.append(rec)
        if report_path:
            report_path.parent.mkdir(parents=True, exist_ok=True)
            report_path.write_text(json.dumps(results, indent=1))

    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    er = sum(r["status"] == "error" for r in results)
    print(f"\n{ok} ok / {sk} skipped / {er} errors")
    if er:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
