"""Simulator CLI: run Eudoxia from a TOML file (paper §4.1.1) with
visual output.

    PYTHONPATH=src python -m repro.launch.sim examples/project.toml \
        [--engine event|python] [--csv out.csv]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.core import run
from repro.core.viz import (
    latency_histogram,
    per_priority_table,
    timeline_csv,
    utilization_timeline,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("paramfile")
    ap.add_argument("--engine", default=None,
                    choices=[None, "event", "python"])
    ap.add_argument("--csv", default=None,
                    help="write the utilisation timeline as CSV")
    ap.add_argument("--json", default=None, help="write the summary JSON")
    args = ap.parse_args()

    res = run(args.paramfile, engine=args.engine)
    s = res.summary()
    print("== summary ==")
    for k in ("submitted", "done", "failed", "throughput_per_s",
              "mean_latency_s", "p99_latency_s", "cpu_utilization",
              "oom_events", "preempt_events", "cost_dollars"):
        print(f"  {k:18s} {s[k]}")
    print("\n== per priority ==")
    print(per_priority_table(res))
    print("\n== utilisation ==")
    print(utilization_timeline(res))
    print("\n== latency distribution ==")
    print(latency_histogram(res))
    if args.csv:
        pathlib.Path(args.csv).write_text(timeline_csv(res))
        print(f"\nwrote {args.csv}")
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps(s, indent=1))
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
