"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16) — the pod
axis is pure data parallel (gradient all-reduce crosses the inter-pod
links once per step; everything bandwidth-hungry stays intra-pod).

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


__all__ = ["make_production_mesh", "make_host_mesh"]
