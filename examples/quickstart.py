"""Quickstart — paper Listing 3, verbatim.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import eudoxia


def main():
    paramfile = str(pathlib.Path(__file__).parent / "project.toml")
    result = eudoxia.run_simulator(paramfile)
    summary = result.summary()
    print("Eudoxia simulation complete:")
    for k in (
        "submitted", "done", "failed", "throughput_per_s",
        "mean_latency_s", "p99_latency_s", "cpu_utilization",
        "oom_events", "preempt_events", "cost_dollars",
    ):
        print(f"  {k:18s} {summary[k]}")


if __name__ == "__main__":
    main()
