"""Chaos walkthrough: a traced run with fault injection on, rendered
as a fault-annotated Gantt plus the chaos event log.

    PYTHONPATH=src python examples/fault_injection.py
    PYTHONPATH=src python examples/fault_injection.py --calm   # same run, faults off

Crashes kill the longest-running container (``X`` on the Gantt),
outages take a whole pool down (its spans die together and the
scheduler routes around it until ``pool_up``), timeouts (``T``) kill
work at its wall-clock deadline, and every kill re-queues under the
exponential-backoff retry policy. See docs/faults.md for the contract.
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import SimParams, run
from repro.core.telemetry.schema import (
    COL_A, COL_B, COL_KIND, COL_OP, COL_PIPE, COL_POOL, COL_TICK, EventKind,
)
from repro.core.types import TICKS_PER_SECOND
from repro.core.viz import pipeline_gantt


def chaos_log(trace):
    """The chaos records, decoded into one line per event."""
    lines = []
    for row in trace.records:
        kind = int(row[COL_KIND])
        tick, pipe, pool = int(row[COL_TICK]), int(row[COL_PIPE]), int(row[COL_POOL])
        t = tick / TICKS_PER_SECOND
        if kind == int(EventKind.FAULT):
            cause = "outage" if int(row[COL_OP]) else "crash"
            lines.append(f"  {t:8.4f}s  fault     pipe {pipe:3d} killed "
                         f"({cause}, pool {pool})")
        elif kind == int(EventKind.POOL_DOWN):
            until = int(row[COL_A]) / TICKS_PER_SECOND
            lines.append(f"  {t:8.4f}s  pool_down pool {pool} masked until "
                         f"{until:.4f}s")
        elif kind == int(EventKind.POOL_UP):
            lines.append(f"  {t:8.4f}s  pool_up   pool {pool} recovered")
        elif kind == int(EventKind.TIMEOUT):
            lines.append(f"  {t:8.4f}s  timeout   pipe {pipe:3d} hit its "
                         f"wall-clock deadline")
        elif kind == int(EventKind.RETRY):
            attempt, release = int(row[COL_A]), int(row[COL_B])
            lines.append(f"  {t:8.4f}s  retry     pipe {pipe:3d} attempt "
                         f"{attempt}, released at "
                         f"{release / TICKS_PER_SECOND:.4f}s")
    return "\n".join(lines) if lines else "  (no chaos events recorded)"


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--calm", action="store_true",
                    help="run the identical workload with faults off")
    args = ap.parse_args(argv)

    params = SimParams(
        duration=0.05,
        scheduling_algo="priority_pool",
        num_pools=2,
        max_pipelines=32,
        max_containers=32,
        waiting_ticks_mean=400.0,
        op_base_seconds_mean=0.004,
        seed=7,
    )
    if not args.calm:
        params = params.replace(
            crash_mtbf_ticks=600.0,        # transient container crashes
            outage_mtbf_ticks=2_000.0,     # whole-pool outages...
            outage_duration_ticks=400.0,   # ...this long
            timeout_ticks=30_000,          # wall-clock kill deadline
            max_retries=3,                 # retry budget before FAILED
            base_backoff_ticks=50,         # backoff = base * 2**attempt
        )

    res = run(params, trace=True)
    s = res.summary()

    print(f"== pipeline gantt ({'calm' if args.calm else 'chaos on'}; "
          f"X = fault kill, T = timeout) ==")
    print(pipeline_gantt(res))

    print("\n== chaos event log ==")
    print(chaos_log(res.trace))

    print(f"\ndone {s['done']}/{s['submitted']}  failed {s['failed']}  "
          f"goodput {s['goodput_per_s']:.1f}/s")
    print(f"faults {s['faults_injected']}  kills {s['fault_kills']}  "
          f"timeouts {s['timeouts']}  retries {s['retries']}")
    print(f"wasted work {s['wasted_work_s']:.4f}s  "
          f"pool down {s['pool_down_s']:.4f}s  mttr {s['mttr_s']:.4f}s")
    if args.calm:
        print("\n(re-run without --calm to inject faults into this workload)")


if __name__ == "__main__":
    main()
