"""End-to-end driver: train a ~100M-param model for a few hundred steps
with checkpointing, failure injection + restart, and straggler
monitoring — the full fault-tolerant loop on CPU.

    PYTHONPATH=src python examples/train_e2e.py
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import dataclasses

from repro.configs.registry import get_arch
from repro.models.common import LayerSpec, ModelConfig
from repro.runtime.failures import FailureInjector, advise_checkpoint_cadence
from repro.runtime.train_loop import run_training


def main():
    # ~100M-param dense LM (phi3 family scaled down)
    arch = get_arch("phi3_mini_3p8b")
    cfg100m = ModelConfig(
        name="phi3_100m",
        family="lm",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab=32256,
        pattern=(LayerSpec("attn", "dense"),),
        attn_impl="ref",
    )
    arch = dataclasses.replace(arch, smoke=cfg100m)

    advice = advise_checkpoint_cadence(
        step_time_s=0.6, ckpt_write_s=1.5, restart_s=10.0, mtbf_steps=120
    )
    print(f"Eudoxia-advised checkpoint interval: {advice['best_interval']}")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        result = run_training(
            arch,
            steps=300,
            global_batch=8,
            seq_len=128,
            ckpt_dir=ckpt_dir,
            ckpt_every=min(advice["best_interval"], 50),
            injector=FailureInjector(seed=3, mtbf_steps=120, max_failures=2),
            microbatches=2,
            on_metrics=lambda s, m: (
                print(f"step {s:4d} loss {m['loss']:.4f}")
                if s % 25 == 0
                else None
            ),
        )
    print(
        f"done: {result.steps_done} steps, loss "
        f"{result.losses[0]:.3f} -> {result.losses[-1]:.3f}, "
        f"{result.restarts} restart(s) from checkpoint, "
        f"{result.straggler_events} straggler event(s)"
    )
    assert result.losses[-1] < result.losses[0]


if __name__ == "__main__":
    main()
