"""Overload walkthrough: one surge tape, two admission policies.

    PYTHONPATH=src python examples/retry_storm.py

Runs the ``retry_storm`` scenario (an arrival surge with a pool outage
in the middle and clients that retry rejected offers with exponential
backoff) twice on the SAME tape — once with ``admit_all`` (the control:
everything reaches the scheduler) and once with a ``queue_threshold``
admission policy (the treatment: excess offers are rejected at the
gate, retried by the client, and eventually shed). It renders each
arm's Gantt, a side-by-side backlog timeline, and the closed-loop
event log, then prints the overload summary metrics — retry
amplification, shed counts, time-to-drain, and the metastability
verdict. See docs/closed-loop.md for the model.
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import SimParams, run
from repro.core.scenarios import retry_storm, retry_storm_params
from repro.core.telemetry.schema import (
    COL_A, COL_KIND, COL_PIPE, COL_POOL, COL_TICK, EventKind,
)
from repro.core.types import TICKS_PER_SECOND
from repro.core.viz import pipeline_gantt
from repro.core.workload import workload_from_trace_records

WIDTH = 72  # columns of the backlog timeline


def base_params():
    return SimParams(
        duration=0.08,
        scheduling_algo="priority_pool",
        num_pools=2,
        max_pipelines=192,
        max_containers=16,
        waiting_ticks_mean=100.0,
        op_base_seconds_mean=0.008,
        op_base_seconds_sigma=1.0,
        total_cpus=4,
        total_ram_gb=8,
        seed=3,
    )


def run_arm(policy: str, records, **knobs):
    params = base_params()
    armed = retry_storm_params(
        params,
        admission_policy=policy,
        outage_mtbf_s=0.02,
        outage_duration_s=0.006,
        client_max_retries=3,
        **knobs,
    ).replace(max_fault_events=2)
    wl = workload_from_trace_records(records, armed)
    return run(armed, workload=wl, trace=True)


def backlog_timeline(res) -> np.ndarray:
    """Outstanding pipelines per time bucket: admitted or waiting at the
    client, arrived but not yet DONE/FAILED (a shed pipeline leaves the
    system at its shed tick)."""
    horizon = res.params.horizon_ticks
    arrival = np.asarray(res.workload.arrival)
    completion = np.asarray(res.state.pipe_completion)
    live = arrival < horizon
    edges = np.linspace(0, horizon, WIDTH + 1)
    centers = (edges[:-1] + edges[1:]) / 2
    return np.array([
        int(np.sum(live & (arrival <= t) & (completion > t)))
        for t in centers
    ])


def outage_columns(trace, horizon: int) -> set[int]:
    cols = set()
    for row in trace.records:
        if int(row[COL_KIND]) == int(EventKind.POOL_DOWN):
            start, until = int(row[COL_TICK]), int(row[COL_A])
            lo = int(start / horizon * WIDTH)
            hi = int(min(until, horizon - 1) / horizon * WIDTH)
            cols.update(range(lo, hi + 1))
    return cols


def render_backlog(label: str, backlog: np.ndarray, outages: set[int]) -> str:
    blocks = " ▁▂▃▄▅▆▇█"
    peak = max(int(backlog.max()), 1)
    bars = "".join(
        blocks[min(int(b / peak * (len(blocks) - 1) + 0.999), len(blocks) - 1)]
        for b in backlog
    )
    marks = "".join("~" if i in outages else " " for i in range(WIDTH))
    return (f"  {label:<16} peak={peak:4d} end={int(backlog[-1]):4d}\n"
            f"  {'':<16} |{bars}|\n"
            f"  {'':<16} |{marks}|  (~ = pool outage)")


def closed_loop_log(trace, limit: int = 12) -> str:
    """The first ``limit`` closed-loop records, one line per event."""
    lines = []
    for row in trace.records:
        kind = int(row[COL_KIND])
        t = int(row[COL_TICK]) / TICKS_PER_SECOND
        pipe = int(row[COL_PIPE])
        if kind == int(EventKind.ADMIT_REJECT):
            lines.append(f"  {t:8.4f}s  admit_reject pipe {pipe:3d} "
                         f"(priority {int(row[COL_A])})")
        elif kind == int(EventKind.CLIENT_RETRY):
            lines.append(f"  {t:8.4f}s  client_retry pipe {pipe:3d} attempt "
                         f"{int(row[COL_A])}")
        elif kind == int(EventKind.SHED):
            lines.append(f"  {t:8.4f}s  shed         pipe {pipe:3d} "
                         f"(retries exhausted)")
        if len(lines) >= limit:
            lines.append(f"  ... ({limit}+ events, truncated)")
            break
    return "\n".join(lines) if lines else "  (no closed-loop events recorded)"


def main(argv=None):
    argparse.ArgumentParser(description=__doc__).parse_args(argv)

    tape_params = base_params().replace(duration=0.06)  # quiet tail
    records = retry_storm(tape_params, seed=3, surge_factor=6.0)

    control = run_arm("admit_all", records)
    treated = run_arm("queue_threshold", records, admit_queue_limit=3)
    horizon = control.params.horizon_ticks

    print("== backlog timeline (outstanding pipelines over time) ==")
    print(render_backlog("admit_all", backlog_timeline(control),
                         outage_columns(control.trace, horizon)))
    print(render_backlog("queue_threshold", backlog_timeline(treated),
                         outage_columns(treated.trace, horizon)))

    print("\n== gantt: queue_threshold (X = fault kill) ==")
    print(pipeline_gantt(treated))

    print("\n== closed-loop event log (queue_threshold arm) ==")
    print(closed_loop_log(treated.trace))

    print("\n== overload summary ==")
    for name, res in (("admit_all", control), ("queue_threshold", treated)):
        s = res.summary()
        drain = ("never drained" if np.isnan(s["time_to_drain_s"])
                 else f"drained {s['time_to_drain_s'] * 1e3:.1f}ms after "
                      "the last fault")
        print(f"  {name:<16} offered {s['offered']:4d}  admitted "
              f"{s['admitted']:4d}  shed {s['shed']:4d}  "
              f"client_retries {s['client_retries']:4d}")
        print(f"  {'':<16} amplification "
              f"{s['retry_amplification']:.2f}x  goodput "
              f"{s['goodput_per_s']:.0f}/s  {drain}  "
              f"metastable={s['metastable']}")
    print("\nThe gate sheds work the fleet cannot serve; admit_all queues "
          "it forever.\nSee docs/closed-loop.md for the client model and "
          "admission-policy authoring.")


if __name__ == "__main__":
    main()
