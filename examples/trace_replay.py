"""Trace replay — the paper's "format existing traces and feed them into
the simulator" path (§3.2.1), plus the §6 claim that "plugging real-world
scaling functions estimated from traces is trivial".

Two parts:

1. **Single replay** — build a JSON trace (here: the TPC-H-like profile
   the validation bench uses), replay it under three schedulers with
   ``run()``, print the comparison.
2. **Fleet replay** — one recorded "day" per fleet lane: four lanes
   drawn from the scenario library (docs/scenarios.md), one per family,
   ingested with ``workload_batch_from_traces`` (capacities derived
   from the traces) and replayed policy-by-policy on the lane-major
   core with ``fleet_run(..., shard="auto")`` — every local device gets
   a slice of the fleet, lanes come back in input order, bitwise what a
   per-lane ``run()`` would produce (tests/test_traces.py proves it).

    PYTHONPATH=src python examples/trace_replay.py
"""
import json
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import (
    SimParams,
    fleet_run,
    fleet_summary,
    load_trace,
    run,
    workload_batch_from_traces,
)
from repro.core.scenarios import list_scenarios, scenario_lane_batch


def single_replay():
    # a mixed analytics trace: 12 queries with measured scaling profiles
    records = []
    profiles = [
        (0.55, 1.0, 4.2), (0.12, 0.5, 2.1), (0.45, 1.0, 5.6),
        (0.30, 1.0, 3.8), (0.50, 1.0, 6.1), (0.18, 1.0, 2.4),
        (0.48, 1.0, 5.9), (0.42, 0.5, 5.2), (0.85, 1.0, 7.8),
        (0.44, 1.0, 6.3), (0.33, 1.0, 3.5), (0.61, 0.5, 4.9),
    ]
    for i, (base_s, alpha, ram) in enumerate(profiles):
        records.append(
            {
                "arrival_s": 0.05 * i,
                "priority": "QUERY" if i % 3 else "INTERACTIVE",
                "ops": [
                    {"ram_gb": ram, "base_s": base_s, "alpha": alpha,
                     "level": 0}
                ],
            }
        )

    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump(records, f)
        trace_path = f.name

    base = SimParams(
        duration=4.0, total_cpus=16.0, total_ram_gb=32.0,
        max_pipelines=32, trace_path=trace_path,
    )
    print("== single trace replay (12-query analytics trace) ==")
    print(f"{'scheduler':12s} {'done':>5s} {'mean_lat':>9s} {'p99':>8s} "
          f"{'util':>6s}")
    for algo in ("naive", "priority", "sjf"):
        wl = load_trace(trace_path, base)
        res = run(base.replace(scheduling_algo=algo), workload=wl)
        s = res.summary()
        print(
            f"{algo:12s} {s['done']:5d} {s['mean_latency_s']:9.4f} "
            f"{s['p99_latency_s']:8.4f} {s['cpu_utilization']:6.3f}"
        )
    pathlib.Path(trace_path).unlink()


def fleet_replay():
    # one lane per scenario family — four recorded "days" in one batch.
    # In production these lists would come from your own trace files
    # (docs/trace-format.md): anything JSON-shaped like
    # [{arrival_s, priority, ops: [...]}, ...] per lane works.
    base = SimParams(
        duration=1.0, waiting_ticks_mean=2000,
        op_base_seconds_mean=0.02, op_ram_gb_mean=2.0,
        num_pools=2, max_containers=64,
        max_pipelines=0, max_ops_per_pipeline=0,  # derive from the traces
    )
    lanes = []
    for i, family in enumerate(list_scenarios()):
        lanes += scenario_lane_batch(family, base, 1, seed=100 + i)

    print("\n== fleet trace replay (one lane per scenario family, "
          "shard='auto') ==")
    print(f"lanes: {len(lanes)}, pipelines/lane: "
          f"{[len(recs) for recs in lanes]}")
    print(f"{'scheduler':14s} {'thr/s':>7s} {'lat_s':>8s} {'util':>6s} "
          f"{'preempt':>8s} {'per-lane done':>20s}")
    for algo in ("naive", "priority", "priority_pool", "sjf"):
        params = base.replace(scheduling_algo=algo)
        # the batch is donated to the compiled core -> rebuild per policy
        wls, params = workload_batch_from_traces(lanes, params)
        states = fleet_run(params, workloads=wls, shard="auto")
        s = fleet_summary(states, params)
        done = [int(d) for d in states.done_count]
        print(
            f"{algo:14s} {s['throughput_per_s_mean']:7.2f} "
            f"{s['mean_latency_s_mean']:8.4f} "
            f"{s['cpu_utilization_mean']:6.3f} "
            f"{s['preempt_events_mean']:8.1f} {str(done):>20s}"
        )


def main():
    single_replay()
    fleet_replay()


if __name__ == "__main__":
    main()
