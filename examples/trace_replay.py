"""Trace replay — the paper's "format existing traces and feed them into
the simulator" path (§3.2.1), plus the §6 claim that "plugging real-world
scaling functions estimated from traces is trivial".

Builds a JSON trace (here: the TPC-H-like profile the validation bench
uses), replays it under two schedulers, and prints the comparison.

    PYTHONPATH=src python examples/trace_replay.py
"""
import json
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import SimParams, load_trace, run


def main():
    # a mixed analytics trace: 12 queries with measured scaling profiles
    records = []
    profiles = [
        (0.55, 1.0, 4.2), (0.12, 0.5, 2.1), (0.45, 1.0, 5.6),
        (0.30, 1.0, 3.8), (0.50, 1.0, 6.1), (0.18, 1.0, 2.4),
        (0.48, 1.0, 5.9), (0.42, 0.5, 5.2), (0.85, 1.0, 7.8),
        (0.44, 1.0, 6.3), (0.33, 1.0, 3.5), (0.61, 0.5, 4.9),
    ]
    for i, (base_s, alpha, ram) in enumerate(profiles):
        records.append(
            {
                "arrival_s": 0.05 * i,
                "priority": "QUERY" if i % 3 else "INTERACTIVE",
                "ops": [
                    {"ram_gb": ram, "base_s": base_s, "alpha": alpha,
                     "level": 0}
                ],
            }
        )

    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump(records, f)
        trace_path = f.name

    base = SimParams(
        duration=4.0, total_cpus=16.0, total_ram_gb=32.0,
        max_pipelines=32, trace_path=trace_path,
    )
    print(f"{'scheduler':12s} {'done':>5s} {'mean_lat':>9s} {'p99':>8s} "
          f"{'util':>6s}")
    for algo in ("naive", "priority", "sjf"):
        wl = load_trace(trace_path, base)
        res = run(base.replace(scheduling_algo=algo), workload=wl)
        s = res.summary()
        print(
            f"{algo:12s} {s['done']:5d} {s['mean_latency_s']:9.4f} "
            f"{s['p99_latency_s']:8.4f} {s['cpu_utilization']:6.3f}"
        )
    pathlib.Path(trace_path).unlink()


if __name__ == "__main__":
    main()
