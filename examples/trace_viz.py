"""Telemetry walkthrough: utilisation timeline, trace-driven Gantt,
Perfetto export.

    PYTHONPATH=src python examples/trace_viz.py            # timelines only
    PYTHONPATH=src python examples/trace_viz.py --trace    # + gantt, perfetto

With ``--trace`` the run records an on-device event trace
(``run(..., trace=True)``), renders the per-pipeline Gantt from its
spans, prints the windowed timeline summary, and writes
``trace_viz.perfetto.json`` — open it at https://ui.perfetto.dev.
See docs/observability.md for the trace schema.
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import SimParams, run, summarize_timeline, to_perfetto_json
from repro.core.viz import pipeline_gantt, utilization_timeline


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", action="store_true",
                    help="record an event trace; adds the Gantt chart, "
                         "windowed metrics, and a Perfetto JSON export")
    ap.add_argument("--trace-capacity", type=int, default=4096,
                    help="trace ring size in records (default 4096)")
    ap.add_argument("--out", default="trace_viz.perfetto.json",
                    help="Perfetto export path (with --trace)")
    args = ap.parse_args(argv)

    params = SimParams(
        duration=0.05,
        scheduling_algo="priority_pool",
        num_pools=2,
        max_pipelines=32,
        max_containers=32,
        waiting_ticks_mean=400.0,
        op_base_seconds_mean=0.004,
        cache_gb_per_pool=4.0,
        scan_ticks_per_gb=50.0,
        cold_start_ticks=40,
        container_warm_ticks=2_000,
    )
    res = run(params, trace=args.trace, trace_capacity=args.trace_capacity)

    print("== utilisation timeline ==")
    print(utilization_timeline(res))
    summary = res.summary()
    print(f"\ndone {summary['done']}/{summary['submitted']}, "
          f"p99 latency {summary['p99_latency_s']:.4f}s")

    if not args.trace:
        print("\n(re-run with --trace for the event-trace views)")
        return

    print(f"\n== pipeline gantt ({res.trace.n} events, "
          f"{res.trace.events_dropped} dropped) ==")
    print(pipeline_gantt(res))

    print("\n== windowed timeline ==")
    tl = summarize_timeline(res.trace, res.params, n_windows=4)
    for w in tl["windows"]:
        print(f"  [{w['t0_s']:.3f}s..{w['t1_s']:.3f}s) "
              f"completed {w['completed']:3d}  "
              f"p99 {w['p99_latency_s']:.4f}s  "
              f"backlog p99 {w['backlog_p99']:.0f}")

    out = pathlib.Path(args.out)
    out.write_text(to_perfetto_json(res.trace, res.params))
    print(f"\nwrote {out} — open in https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
