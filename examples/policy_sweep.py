"""Monte-Carlo policy evaluation with lane-major fleets — the
device-scale payoff of the SoA simulator redesign (DESIGN.md §2).

Runs a fleet of simulations per (policy x seed) entirely inside XLA —
sharded across every local device (``shard="auto"``; force several on
CPU with XLA_FLAGS=--xla_force_host_platform_device_count=4) — and
prints the aggregate comparison a platform team would use to pick a
scheduler.

    PYTHONPATH=src python examples/policy_sweep.py
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import SimParams, fleet_run, fleet_summary


def main():
    base = SimParams(
        duration=1.0,
        waiting_ticks_mean=2500,
        op_base_seconds_mean=0.02,
        op_ram_gb_mean=2.0,
        max_pipelines=64,
        max_containers=64,
    )
    seeds = list(range(32))
    print(f"fleet: {len(seeds)} seeds x 3 policies")
    print(f"{'policy':16s} {'thr/s':>10s} {'±':>8s} {'lat(s)':>10s} "
          f"{'util':>7s} {'oom':>6s} {'preempt':>8s} {'wall(s)':>8s}")
    for policy in ("naive", "priority", "priority_pool"):
        params = base.replace(
            scheduling_algo=policy,
            num_pools=2 if policy == "priority_pool" else 1,
        )
        t0 = time.time()
        states = fleet_run(params, seeds, shard="auto")
        s = fleet_summary(states, params)
        wall = time.time() - t0
        print(
            f"{policy:16s} {s['throughput_per_s_mean']:10.2f} "
            f"{s['throughput_per_s_std']:8.2f} {s['mean_latency_s_mean']:10.4f} "
            f"{s['cpu_utilization_mean']:7.3f} {s['oom_events_mean']:6.1f} "
            f"{s['preempt_events_mean']:8.1f} {wall:8.2f}"
        )


if __name__ == "__main__":
    main()
