"""Custom scheduler registration — paper Listings 4-6, verbatim API.

Implements a smallest-job-first (SJF-by-op-count) policy with the exact
decorator + signature contract from the paper, runs it against the
built-in priority scheduler on the same workload, and prints the
comparison.

    PYTHONPATH=src python examples/custom_scheduler.py
"""
import pathlib
import sys
from typing import List

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from eudoxia.core import Scheduler
from eudoxia.core import Failure, Assignment, Pipeline
from eudoxia.algorithm import register_scheduler, register_scheduler_init

from repro.core import SimParams, generate_workload, run


@register_scheduler_init(key="my-scheduler")
def scheduler_init(sch: Scheduler):
    sch.data["chunk"] = 0.25  # allocate quarter-pool containers


@register_scheduler(key="my-scheduler")
def scheduler_algo(sch: Scheduler, f: List[Failure], p: List[Pipeline]):
    suspends, assignments = [], []
    frac = sch.data["chunk"]
    want_cpu = frac * sch.pool_cpu_cap[0]
    want_ram = frac * sch.pool_ram_cap[0]
    free_cpu = list(sch.pool_cpu_free)
    free_ram = list(sch.pool_ram_free)
    # smallest job first (by op count, then priority)
    for pid in sorted(
        sch.waiting_pids(),
        key=lambda pid: (sch.pipeline(pid).num_ops, -int(sch.pipeline(pid).priority)),
    ):
        pipe = sch.pipeline(pid)
        cpu = max(want_cpu, pipe.last_cpus * 2 if pipe.failed_before else want_cpu)
        ram = max(want_ram, pipe.last_ram_gb * 2 if pipe.failed_before else want_ram)
        if free_cpu[0] >= cpu and free_ram[0] >= ram:
            assignments.append(Assignment(pipe, 0, cpu, ram))
            free_cpu[0] -= cpu
            free_ram[0] -= ram
    return suspends, assignments


def main():
    params = SimParams(
        duration=2.0,
        waiting_ticks_mean=4000,
        op_base_seconds_mean=0.02,
        op_ram_gb_mean=1.5,
        seed=7,
        scheduling_algo="my-scheduler",
        engine="python",            # custom Python schedulers run here
    )
    wl = generate_workload(params)
    mine = run(params, workload=wl).summary()
    base = run(
        params.replace(scheduling_algo="priority", engine="event"),
        workload=wl,
    ).summary()
    print(f"{'metric':22s} {'my-scheduler':>14s} {'priority':>14s}")
    for k in ("done", "throughput_per_s", "mean_latency_s", "p99_latency_s",
              "cpu_utilization", "oom_events"):
        print(f"{k:22s} {mine[k]!s:>14.14s} {base[k]!s:>14.14s}")


if __name__ == "__main__":
    main()
